"""Scenario runner: end-to-end workloads as self-scoring eval harnesses.

A *scenario* is a named build → sample → score pipeline: construct a
model MPS, run the full sampling stack through the public session API,
and score the output against an exact oracle or a task metric.  Each run
emits one BENCH-trajectory row (the :mod:`benchmarks.common` record
schema), so scenario quality is tracked across PRs exactly like the perf
numbers — a regression in sampler correctness shows up as a score drop
in the same file.

Shipped scenarios
-----------------
``gbs``
    The paper's workload: a GBS-flavoured linear MPS; empirical per-site
    marginals vs :func:`repro.core.mps.exact_site_marginals`.
``conditional_marginals``
    The tentpole's acceptance harness: clamp one site, estimate the
    conditional marginals of the *other* sites with the per-sample
    ``log_prob`` importance weights, and compare against conditionals
    computed by restricting the exact joint.  Passing means the clamped
    walk's weights are the true branch probabilities — the rejection-free
    conditioning claim, end to end.
``mnist_classify_generate``
    A Born-machine-style generate/classify loop on 4×4 binary digit
    prototypes: one product-form MPS per class (pixel flip noise 0.1),
    generate from each, classify every sample by per-class
    log-likelihood.  Scores generative-model fidelity rather than a
    distributional distance.

Register new scenarios with the :func:`scenario` decorator; the CLI
(``python -m repro.launch.scenarios``) and the CI smoke job pick them up
from the registry.
"""
from __future__ import annotations

import dataclasses
import datetime
import itertools
import json
import os
import tempfile
import time
from typing import Callable, Optional

import numpy as np

__all__ = ["ScenarioConfig", "ScenarioResult", "available_scenarios",
           "run_scenario", "scenario"]


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """Runner knobs shared by every scenario (scenario-specific sizes are
    fixed by the scenario itself so scores stay comparable across runs)."""

    n_samples: int = 4000
    seed: int = 0
    backend: str = "inmem"        # "inmem" | "streamed"
    scheme: str = "seq"           # "seq" | "dp"
    json_path: Optional[str] = None   # BENCH trajectory (None/"" = no append)


@dataclasses.dataclass(frozen=True)
class ScenarioResult:
    name: str
    passed: bool
    score: float                  # scenario-native quality number
    threshold: float              # pass bar (direction is per-metric)
    wall_s: float
    metrics: dict

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


# -- registry -----------------------------------------------------------------

_REGISTRY: dict[str, Callable] = {}


def scenario(name: str, summary: str):
    """Register ``fn(cfg: ScenarioConfig) -> (passed, score, threshold,
    metrics)`` under ``name``."""
    def deco(fn):
        fn.scenario_name = name
        fn.summary = summary
        _REGISTRY[name] = fn
        return fn
    return deco


def available_scenarios() -> dict[str, str]:
    """{name: one-line summary} for the CLI and docs."""
    return {n: f.summary for n, f in sorted(_REGISTRY.items())}


def _append_record(json_path: Optional[str], bench: str, config: dict,
                   **payload) -> dict:
    """One BENCH-trajectory row.  ``benchmarks/`` is a repo-root package
    not importable under the library's ``PYTHONPATH=src`` deployments, so
    this falls back to an inline writer with the identical record schema
    — the trajectory file cannot tell the two writers apart."""
    try:
        from benchmarks.common import append_bench_record
        return append_bench_record(json_path, bench, config, **payload)
    except ImportError:
        pass
    record = {
        "bench": bench,
        "utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "config": config,
        **payload,
    }
    if not json_path:
        return record
    trajectory = []
    if os.path.exists(json_path):
        with open(json_path) as f:
            trajectory = json.load(f)
    trajectory.append(record)
    with open(json_path, "w") as f:
        json.dump(trajectory, f, indent=1)
    return record


def run_scenario(name: str, cfg: Optional[ScenarioConfig] = None
                 ) -> ScenarioResult:
    """Run one registered scenario and append its trajectory row."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown scenario {name!r}; available: "
                       f"{sorted(_REGISTRY)}")
    cfg = cfg or ScenarioConfig()
    t0 = time.perf_counter()
    passed, score, threshold, metrics = _REGISTRY[name](cfg)
    wall = time.perf_counter() - t0
    result = ScenarioResult(name=name, passed=bool(passed),
                            score=float(score), threshold=float(threshold),
                            wall_s=wall, metrics=metrics)
    _append_record(
        cfg.json_path, "scenario",
        {"scenario": name, "n_samples": cfg.n_samples, "seed": cfg.seed,
         "backend": cfg.backend, "scheme": cfg.scheme},
        passed=result.passed, score=result.score,
        threshold=result.threshold, wall_s=round(wall, 4), metrics=metrics)
    return result


# -- shared sampling helper ---------------------------------------------------

def _sample(mps, n: int, cfg: ScenarioConfig, clamp=None):
    """One session run through the PUBLIC API → (samples (N, M), stats).

    ``backend="streamed"`` round-trips the MPS through a temporary
    full-precision GammaStore so the scenario exercises the segment
    walker + digest-manifest path rather than the in-memory scan.
    """
    import jax

    from repro import api
    config = api.SamplerConfig(scheme=cfg.scheme, backend=cfg.backend,
                               clamp=clamp)
    key = jax.random.key(cfg.seed + 1)
    mesh = (jax.make_mesh((jax.device_count(),), ("data",))
            if cfg.scheme == "dp" else None)
    if cfg.backend == "streamed":
        import jax.numpy as jnp

        from repro.data.gamma_store import GammaStore
        rdt = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
        with tempfile.TemporaryDirectory(prefix="scenario_store_") as tmp:
            with GammaStore(os.path.join(tmp, "store"), storage_dtype=rdt,
                            compute_dtype=rdt) as store:
                store.write_mps(mps)
                store.write_digest_manifest()
                with api.SamplingSession(store, config, mesh=mesh) as session:
                    out = session.sample(n, key)
                    return np.asarray(out), dict(session.stats)
    with api.SamplingSession(mps, config, mesh=mesh) as session:
        out = session.sample(n, key)
        return np.asarray(out), dict(session.stats)


# -- scenarios ----------------------------------------------------------------

@scenario("gbs", "GBS workload: empirical site marginals vs exact oracle")
def _gbs(cfg: ScenarioConfig):
    import jax

    from repro.core import mps as M
    sites, chi, d = 8, 4, 3
    mps = M.gbs_like_mps(jax.random.key(cfg.seed), sites, chi, d)
    samples, _ = _sample(mps, cfg.n_samples, cfg)
    exact = M.exact_site_marginals(mps)
    emp = np.stack([(samples == s).mean(axis=0) for s in range(d)], axis=1)
    err = float(np.abs(emp - exact).max())
    threshold = 0.05   # ~4.5σ at N=4000 for a worst-case p=0.5 cell
    return err < threshold, err, threshold, {
        "sites": sites, "chi": chi, "d": d,
        "mean_photons": float(samples.mean())}


@scenario("conditional_marginals",
          "clamped sampling vs exact conditionals (the tentpole gate)")
def _conditional_marginals(cfg: ScenarioConfig):
    import jax

    from repro.core import mps as M
    sites, chi, d = 6, 4, 3
    clamp_site, clamp_val = 2, 1
    mps = M.random_linear_mps(jax.random.key(cfg.seed), sites, chi, d)
    samples, stats = _sample(mps, cfg.n_samples, cfg,
                             clamp={clamp_site: clamp_val})
    if not np.all(samples[:, clamp_site] == clamp_val):
        return False, float("inf"), 0.0, {"error": "clamp not enforced"}
    lp = np.asarray(stats["log_prob"], dtype=np.float64)
    w = np.exp(lp)

    # oracle: restrict the exact joint to the clamped branch, renormalize
    joint = M.enumerate_probabilities(mps)
    outs = np.array(list(itertools.product(range(d), repeat=sites)))
    sel = outs[:, clamp_site] == clamp_val
    cond = joint[sel] / joint[sel].sum()
    outs_c = outs[sel]

    # estimator: self-normalized importance weights.  w = P(branch) per
    # sample, identical across samples for a scalar clamp, so this reduces
    # to plain frequencies — but the weighted form is what generalizes to
    # per-sample clamps, so score THAT path.
    err = 0.0
    for i in range(sites):
        if i == clamp_site:
            continue
        for s in range(d):
            est = float(w[samples[:, i] == s].sum() / w.sum())
            exact = float(cond[outs_c[:, i] == s].sum())
            err = max(err, abs(est - exact))
    # the branch-marginal estimate: E[w] = P(clamp); w varies only through
    # the sampled prefix s_{<clamp}, so the MC error is tiny but not zero
    p_branch = float(joint[sel].sum())
    branch_err = abs(float(w.mean()) - p_branch)
    threshold = 0.05
    return (err < threshold and branch_err < 5e-3), err, threshold, {
        "clamp": {str(clamp_site): clamp_val},
        "p_branch_exact": p_branch, "p_branch_est": float(w.mean()),
        "branch_err": branch_err}


#: 4×4 binary digit prototypes (one per class) for the generate/classify
#: loop — distinct in ≥ 5 pixels pairwise, so flip noise 0.1 is separable
_DIGITS = {
    0: ("1111", "1001", "1001", "1111"),
    1: ("0010", "0110", "0010", "0111"),
    2: ("1110", "0010", "0100", "1111"),
    3: ("1111", "0001", "0111", "1110"),
}
_FLIP = 0.1


def _digit_mps(cls: int):
    """Class prototype → a product-form linear MPS over 16 binary sites:
    ``gammas[i, 0, 0, s] = p_i(s)`` with flip noise, everything else 0
    (χ=2 embedding; only bond index 0 is reachable from the boundary)."""
    import jax.numpy as jnp

    from repro.core.mps import MPS
    bits = [int(b) for row in _DIGITS[cls] for b in row]
    g = np.zeros((16, 2, 2, 2))
    for i, b in enumerate(bits):
        g[i, 0, 0, b] = 1.0 - _FLIP
        g[i, 0, 0, 1 - b] = _FLIP
    return MPS(jnp.asarray(g), jnp.ones((16, 2)), "linear"), bits


def _digit_loglik(samples: np.ndarray, bits: list[int]) -> np.ndarray:
    """(N, 16) binary samples → per-sample log-likelihood under a class."""
    proto = np.asarray(bits)[None, :]
    match = samples == proto
    return np.where(match, np.log(1.0 - _FLIP), np.log(_FLIP)).sum(axis=1)


@scenario("mnist_classify_generate",
          "per-class digit MPS: generate samples, classify by log-likelihood")
def _mnist(cfg: ScenarioConfig):
    per_class = max(cfg.n_samples // (4 * 8), 25)   # cheap: 4 full sessions
    all_samples, labels, protos = [], [], {}
    for cls in sorted(_DIGITS):
        mps, bits = _digit_mps(cls)
        protos[cls] = bits
        sub = dataclasses.replace(cfg, seed=cfg.seed + 17 * (cls + 1))
        samples, _ = _sample(mps, per_class, sub)
        all_samples.append(samples)
        labels.append(np.full(len(samples), cls))
    samples = np.concatenate(all_samples)
    labels = np.concatenate(labels)
    loglik = np.stack([_digit_loglik(samples, protos[c])
                       for c in sorted(protos)], axis=1)
    pred = loglik.argmax(axis=1)
    acc = float((pred == labels).mean())
    threshold = 0.9
    flip_rate = float(np.concatenate([
        s != np.asarray(protos[c])[None, :]
        for s, c in zip(all_samples, sorted(protos))], axis=0).mean())
    return acc >= threshold, acc, threshold, {
        "per_class": per_class, "classes": len(protos),
        "observed_flip_rate": flip_rate, "nominal_flip_rate": _FLIP}
