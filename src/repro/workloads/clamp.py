"""Clamp specs: the conditional-sampling contract (workloads pillar 1).

A *clamp* fixes the outcome of a subset of sites while the sampler walks
the rest of the chain as usual — a forced draw into the existing collapse
path, not a rejection filter.  The spec is carried on the session-level
:class:`repro.api.SamplerConfig` and travels the whole stack (plan →
engine → kernel dispatch → remote payload → gateway schema), so it must
be (a) hashable — session plans and service coalescing cells contain the
config — and (b) JSON-round-trippable — the v2 job-batch payload and the
gateway job schema serialize it.

Canonical form (what :func:`normalize_clamp` produces)::

    ((site, outcome), ...)            # sorted by site
    ((site, (o_0, ..., o_{N-1})), ...)  # per-sample outcomes

Accepted inputs: ``None`` / ``{}`` (no clamp — normalizes to ``None`` so
an empty clamp routes through the *unchanged* unclamped code path,
bit-identical by construction), a ``{site: outcome}`` mapping (JSON
object keys arrive as strings — coerced), a ``{site: [per-sample
outcomes]}`` mapping, or an already-canonical pair sequence.

This module is a leaf (numpy only): ``repro.api.config`` normalizes with
it at config construction, ``repro.core.clamped`` builds traced arrays
from it, and the gateway's 400-on-malformed behaviour is exactly the
:class:`ValueError` raised here surfacing through ``config_from_dict``.
"""
from __future__ import annotations

from typing import Optional, Union

import numpy as np

ClampSpec = Optional[tuple]


def _as_site(k) -> int:
    try:
        site = int(k)
    except (TypeError, ValueError):
        raise ValueError(f"clamp site {k!r} is not an integer") from None
    if isinstance(k, float) and k != site:
        raise ValueError(f"clamp site {k!r} is not an integer")
    if site < 0:
        raise ValueError(f"clamp site {site} is negative")
    return site


def _as_outcome(site: int, v) -> Union[int, tuple]:
    if isinstance(v, (str, bytes, dict)):
        raise ValueError(f"clamp outcome for site {site} must be an integer "
                         f"or a per-sample integer sequence, got {v!r}")
    if np.isscalar(v) or (isinstance(v, np.ndarray) and v.ndim == 0):
        try:
            o = int(v)
        except (TypeError, ValueError):
            raise ValueError(f"clamp outcome {v!r} for site {site} is not "
                             f"an integer") from None
        if o < 0:
            raise ValueError(f"clamp outcome {o} for site {site} is negative")
        return o
    try:
        seq = [int(x) for x in np.asarray(v).ravel().tolist()]
    except (TypeError, ValueError):
        raise ValueError(f"clamp outcome {v!r} for site {site} is not an "
                         f"integer or integer sequence") from None
    if not seq:
        raise ValueError(f"clamp for site {site} is an empty sequence")
    if any(o < 0 for o in seq):
        raise ValueError(f"clamp for site {site} contains negative outcomes")
    return tuple(seq)


def normalize_clamp(clamp) -> ClampSpec:
    """Any accepted input → the canonical hashable spec (or ``None``).

    Raises ``ValueError`` on malformed specs — the gateway surfaces this
    as a clean 400 via ``config_from_dict``."""
    if clamp is None:
        return None
    if isinstance(clamp, dict):
        items = clamp.items()
    elif isinstance(clamp, (tuple, list)):
        items = []
        for pair in clamp:
            if (isinstance(pair, (str, bytes)) or
                    not hasattr(pair, "__len__") or len(pair) != 2):
                raise ValueError(f"clamp entry {pair!r} is not a "
                                 f"(site, outcome) pair")
            items.append((pair[0], pair[1]))
    else:
        raise ValueError(f"clamp must be a mapping or a (site, outcome) "
                         f"pair sequence, got {type(clamp).__name__}")
    out = {}
    for k, v in items:
        site = _as_site(k)
        if site in out:
            raise ValueError(f"clamp names site {site} twice")
        out[site] = _as_outcome(site, v)
    if not out:
        return None                     # empty ≡ unclamped, literally
    return tuple(sorted(out.items()))


def validate_clamp(clamp: ClampSpec, *, n_sites: int, d: int,
                   n_samples: Optional[int] = None) -> None:
    """Range-check a normalized spec against a concrete chain/batch.

    Plan-time validation: site ∈ [0, n_sites), outcome ∈ [0, d), and a
    per-sample sequence must cover exactly ``n_samples`` samples."""
    if clamp is None:
        return
    for site, outcome in clamp:
        if site >= n_sites:
            raise ValueError(f"clamp site {site} is outside the chain "
                             f"(n_sites={n_sites})")
        vals = outcome if isinstance(outcome, tuple) else (outcome,)
        for o in vals:
            if o >= d:
                raise ValueError(f"clamp outcome {o} at site {site} is "
                                 f"outside the physical dimension (d={d})")
        if isinstance(outcome, tuple) and n_samples is not None \
                and len(outcome) != n_samples:
            raise ValueError(f"per-sample clamp at site {site} covers "
                             f"{len(outcome)} samples, batch has "
                             f"{n_samples}")


def clamp_map(clamp: ClampSpec) -> Optional[dict]:
    """Canonical spec → ``{site: int | (N,) int32 array}`` for array
    construction (``None`` for no clamp)."""
    if clamp is None:
        return None
    return {site: (np.asarray(outcome, dtype=np.int32)
                   if isinstance(outcome, tuple) else int(outcome))
            for site, outcome in clamp}


def segment_clamp_arrays(cmap: dict, start: int, length: int,
                         n_samples: int) -> tuple[np.ndarray, np.ndarray]:
    """Traced-operand view of the clamp for sites [start, start+length).

    Returns ``(mask (L,) bool, vals (L, N) int32)``.  Sites past the
    chain end (the streaming engine's identity pad sites) are simply
    absent from ``cmap`` and stay unmasked, so pads contribute neither
    forced outcomes nor log-probability."""
    mask = np.zeros((length,), dtype=bool)
    vals = np.zeros((length, n_samples), dtype=np.int32)
    for site, outcome in cmap.items():
        if start <= site < start + length:
            mask[site - start] = True
            vals[site - start, :] = outcome   # scalar broadcasts; (N,) copies
    return mask, vals


def parse_clamp_arg(text: str) -> Optional[dict]:
    """CLI syntax ``"site=outcome,site=outcome,..."`` → a clamp mapping.

    Used by ``launch/sample.py --clamp``; raises ``ValueError`` with the
    offending token on malformed input."""
    if not text:
        return None
    out = {}
    for tok in text.split(","):
        tok = tok.strip()
        if not tok:
            continue
        if "=" not in tok:
            raise ValueError(f"clamp token {tok!r} is not site=outcome")
        s, o = tok.split("=", 1)
        try:
            out[int(s)] = int(o)
        except ValueError:
            raise ValueError(f"clamp token {tok!r} is not "
                             f"integer=integer") from None
    return out or None


__all__ = ["ClampSpec", "clamp_map", "normalize_clamp", "parse_clamp_arg",
           "segment_clamp_arrays", "validate_clamp"]
