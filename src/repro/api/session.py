"""`SamplingSession` — one front door for every FastMPS sampling mode.

The session owns the source (an in-memory :class:`MPS`, an on-disk
:class:`GammaStore`, or a store path), resolves a :class:`SamplerConfig`
against it, and routes ``sample(n, key)`` to a registered backend.  Every
level of the paper's multi-level design composes behind that single call:

* macro batches N₁ as idempotent :class:`WorkQueue` items (``run_queue``),
* micro batches N₂ under every scheme (§3.1, Eq. 3),
* DP × TP placement over the session's mesh (§3.1–§3.2, Eq. 7 selector),
* dynamic bond dimensions via a bucketed χ-profile (§3.4.2),
* segment streaming with compute/I-O overlap (§3.1/§3.3.2),
* per-segment checkpoints + bit-exact mid-chain resume (§4.1).

Typical use::

    from repro import api

    with api.SamplingSession(mps) as session:           # in-memory
        samples = session.sample(4096, jax.random.key(0))

    cfg = api.SamplerConfig(backend="streamed", checkpoint_dir=ckpt)
    with api.SamplingSession(store, cfg, mesh=mesh) as session:
        print(session.explain(4096))                    # why this plan
        samples = session.sample(4096, key)             # streamed DP/TP
        resumed = session.sample(4096, key, resume=True)

``session.plan(n)`` returns the fully-resolved :class:`SessionPlan`;
``session.explain(n)`` adds the perfmodel's §3.1 overlap accounting.
"""
from __future__ import annotations

import os
import tempfile
import threading
from typing import Optional, Union

import jax
import numpy as np

from repro.api.backends import SampleRequest, get_backend
from repro.api.config import SamplerConfig, SessionPlan, resolve_plan
from repro.api.runtime import ClusterRuntime, resolve_runtime
from repro.core.mps import MPS
from repro.data.gamma_store import GammaStore


class SamplingSession:
    """Facade over the (data plane × runtime) registries; see module
    docstring."""

    def __init__(self, source: Union[MPS, GammaStore, str, os.PathLike],
                 config: Optional[SamplerConfig] = None, *, mesh=None):
        self.config = config or SamplerConfig()
        self.mesh = mesh
        # the cluster runtime is session state (it may hold live transport
        # handles); plans record only its name.  A runtime resolved from a
        # name here is session-owned (its persistent workers are reaped on
        # close); an instance passed in stays the caller's
        self.runtime = resolve_runtime(self.config.runtime)
        self._owns_runtime = not isinstance(self.config.runtime,
                                            ClusterRuntime)
        self._mps: Optional[MPS] = None
        self._store: Optional[GammaStore] = None
        self._owns_store = False
        self._tmp_store_root: Optional[str] = None
        self._plans: dict[int, SessionPlan] = {}
        self.stats: dict = {}           # last sample()'s engine statistics
        # service workers drive the session concurrently: plan resolution /
        # source materialization must be race-free
        self._state_lock = threading.RLock()
        # streamed engines, cached per plan so repeated batches of one job
        # reuse ONE compilation and the prefetch pool can gang-schedule
        # across batch boundaries (closed with the session)
        self._engines: dict = {}
        self._service = None            # lazy one-lane service behind sample()

        if isinstance(source, (str, os.PathLike)):
            source = GammaStore(str(source))
            self._owns_store = True
        if isinstance(source, GammaStore):
            self._store = source
            if source.n_sites == 0:
                raise ValueError(f"empty GammaStore at {source.root}")
            shape = source.meta(0)      # header-only probe
            self.n_sites, self.chi, self.d = (source.n_sites, shape[0],
                                              shape[2])
            self._source_semantics = None
            self._backend_hint = "streamed"
            self._elt_bytes = np.dtype(source.compute_dtype).itemsize
        elif isinstance(source, MPS):
            self._mps = source
            self.n_sites, self.chi, self.d = (source.n_sites, source.chi,
                                              source.phys_dim)
            self._source_semantics = source.semantics
            self._backend_hint = "inmem"
            self._elt_bytes = np.dtype(source.gammas.dtype).itemsize
        else:
            raise TypeError(f"source must be an MPS, a GammaStore, or a "
                            f"store path — got {type(source).__name__}")

    # -- planning ------------------------------------------------------------
    def plan(self, n_samples: int) -> SessionPlan:
        """The fully-resolved execution plan for ``sample(n_samples, ...)``."""
        with self._state_lock:
            if n_samples not in self._plans:
                self._plans[n_samples] = resolve_plan(
                    self.config, n_samples=n_samples, n_sites=self.n_sites,
                    chi=self.chi, d=self.d, mesh=self.mesh,
                    source_semantics=self._source_semantics,
                    backend_hint=self._backend_hint,
                    elt_bytes=self._elt_bytes, runtime=self.runtime)
            return self._plans[n_samples]

    def explain(self, n_samples: int) -> dict:
        """``plan()`` plus the perfmodel accounting behind the AUTO choices."""
        plan = self.plan(n_samples)
        stages = plan.stages or ((0, self.n_sites, self.chi),)
        info = {
            "backend": plan.backend, "runtime": plan.runtime,
            "processes": self.runtime.process_count,
            "scheme": plan.scheme, "kernels": plan.kernels,
            "semantics": plan.semantics, "p1": plan.p1, "p2": plan.p2,
            "micro_batch": plan.micro_batch,
            "n_stages": len(stages),
            "chi_buckets": sorted({chi_s for _, _, chi_s in stages}),
        }
        if plan.backend == "streamed":
            from repro.core.perfmodel import Workload
            from repro.engine.planner import explain_plan
            from repro.engine.streaming import StreamPlan
            w = Workload(n_samples=n_samples, n_sites=self.n_sites,
                         chi=self.chi, d=self.d, macro_batch=n_samples,
                         micro_batch=(plan.micro_batch or n_samples))
            engine_info = explain_plan(
                StreamPlan(segment_len=plan.segment_len,
                           scheme=("inmem" if plan.scheme == "seq"
                                   else plan.scheme),
                           micro_batch=plan.micro_batch),
                w, self.config.hardware, compute_bytes=self._elt_bytes)
            engine_info.pop("scheme", None)      # keep the session-level name
            info.update(engine_info)
            if plan.shard_block:
                from repro.core.perfmodel import shard_wire_bytes
                info["shard"] = {
                    "block": plan.shard_block,
                    "hosts": self.runtime.process_count,
                    **shard_wire_bytes(w, self.runtime.process_count,
                                       block=plan.shard_block),
                }
        return info

    # -- source materialization (lazy; at most once per session) -------------
    def _ensure_mps(self) -> MPS:
        with self._state_lock:
            if self._mps is None:
                import jax.numpy as jnp
                g, lam = self._store.get_segment(0, self.n_sites,
                                                 prefetch_next_segment=False)
                semantics = (self.config.semantics
                             if self.config.semantics != "auto" else "linear")
                self._mps = MPS(jnp.asarray(g), jnp.asarray(lam), semantics)
            return self._mps

    def _ensure_store(self) -> GammaStore:
        with self._state_lock:
            if self._store is None:
                root = self.config.store_root
                if root is None:
                    root = tempfile.mkdtemp(prefix="fastmps_session_")
                    self._tmp_store_root = root
                # identity storage dtype: a session-materialized store must
                # not round Γ, or the streamed backend would diverge bit-wise
                # from the in-memory one (callers wanting bf16 storage build
                # the GammaStore themselves)
                dt = self._mps.gammas.dtype
                self._store = GammaStore(root, storage_dtype=dt,
                                         compute_dtype=dt)
                if self._store.n_sites == 0:
                    self._store.write_mps(self._mps)
                self._owns_store = True
            return self._store

    # -- execution -----------------------------------------------------------
    def _default_service(self):
        """The session's private one-lane :class:`SamplingService` —
        ``sample()``/``run_queue()`` are synchronous wrappers over it, so
        there is exactly ONE execution path (the service's batch runner)."""
        with self._state_lock:
            if self._service is None:
                from repro.api.service import SamplingService
                self._service = SamplingService(workers=1)
            return self._service

    def sample(self, n_samples: int, key: jax.Array, *, resume: bool = False,
               checkpoint_dir: Optional[str] = None,
               stop_after_segments: Optional[int] = None) -> np.ndarray:
        """Draw ``n_samples`` chains; returns (N, M) int32 outcomes.

        A thin synchronous wrapper: the call is a single-macro-batch job on
        the session's private :class:`~repro.api.service.SamplingService`
        (same key, so bit-identity with pre-service releases holds — see
        ``service.batch_key``); multi-batch/async callers use a service
        directly.  ``resume=True`` continues a killed streamed run from its
        newest checkpoint (bit-identical to the uninterrupted run, paper
        §4.1).  ``checkpoint_dir`` overrides the config's (e.g. one dir per
        macro batch); ``stop_after_segments`` is the failure-injection hook
        tests use to simulate a mid-chain kill.
        """
        handle = self._default_service().submit(
            self, n_samples=n_samples, key=key, macro_batches=1,
            resume=resume, checkpoint_dir=checkpoint_dir,
            stop_after_segments=stop_after_segments)
        return handle.result()

    def _execute_batch(self, n_samples: int, key: jax.Array, *, job=None,
                       resume: bool = False,
                       checkpoint_dir: Optional[str] = None,
                       stop_after_segments: Optional[int] = None,
                       pipeline: bool = False) -> tuple[np.ndarray, dict]:
        """Run ONE macro batch on the data plane — the service's batch
        runner, and the only place a backend is invoked.  ``key`` is the
        *job* key: the local schedule folds it per :func:`service.batch_key`;
        the remote data plane ships it unfolded with the ``job`` identity so
        the worker side folds identically (the job batch, not the whole run,
        is the dispatch unit).  Returns ``(samples, stats)`` — stats by
        value, so concurrent lanes never read another batch's numbers off
        the shared ``self.stats`` attribute (kept for the synchronous
        facade)."""
        from repro.api.service import batch_key

        plan = self.plan(n_samples)
        if job is not None and plan.backend != "remote":
            key = batch_key(key, job.batch_id, job.n_batches)
        # the config-level checkpoint_dir names ONE chain walk's directory —
        # a multi-batch job must not fall back to it, or every batch would
        # overwrite the same site_*/samples_* files (use checkpoint_root,
        # which the scheduler expands to per-batch subdirs)
        if checkpoint_dir is None and (job is None or job.n_batches == 1):
            checkpoint_dir = self.config.checkpoint_dir
        req = SampleRequest(
            plan=plan, n_samples=n_samples, key=key, mesh=self.mesh,
            mps=self._ensure_mps, store=self._ensure_store,
            runtime=self.runtime, config=self.config, resume=resume,
            checkpoint_dir=checkpoint_dir,
            stop_after_segments=stop_after_segments,
            job=job, pipeline=pipeline, engines=self._engines)
        out = get_backend(plan.backend).sample(req)
        self.stats = req.stats
        return out, dict(req.stats)

    def run_queue(self, queue, per_batch: int, base_key: jax.Array, *,
                  worker: str = "session", checkpoint_root: Optional[str] = None,
                  on_batch=None) -> dict[int, np.ndarray]:
        """Macro batches (paper N₁) as idempotent work items.

        A thin synchronous wrapper over the service execution path: each
        batch claimed from the *caller's* queue (whose state is the restart
        unit — two sessions sharing one queue split the work) runs as a
        single-batch service job via :meth:`sample`.  Callers that don't
        need an external queue should submit one multi-batch job to a
        :class:`~repro.api.service.SamplingService` instead and stream it.

        Batch b is fully determined by ``fold_in(base_key, b)``, so the
        :class:`WorkQueue`'s elasticity/restart guarantees hold verbatim:
        completed batches are never recomputed and results are
        owner-independent.  With ``checkpoint_root``, each batch checkpoints
        into its own subdirectory and a mid-batch kill resumes from the last
        segment boundary (streamed backend).  ``on_batch(b, samples)`` is
        called per finished batch (e.g. to persist it); without it the
        samples are collected and returned.
        """
        import shutil

        from repro.api.service import (batch_checkpoint_dir,
                                       has_chain_checkpoint)

        streamed = self.plan(per_batch).backend == "streamed"
        out: dict[int, np.ndarray] = {}
        while (b := queue.claim(worker)) is not None:
            ck, resume = None, False
            if checkpoint_root and streamed:
                ck = batch_checkpoint_dir(checkpoint_root, b)
                os.makedirs(ck, exist_ok=True)
                resume = has_chain_checkpoint(ck)
            res = self.sample(per_batch, jax.random.fold_in(base_key, b),
                              resume=resume, checkpoint_dir=ck)
            if on_batch is not None:
                on_batch(b, res)
            else:
                out[b] = res
            if ck:
                shutil.rmtree(ck, ignore_errors=True)  # batch output durable
            queue.complete(b)
        return out

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Release session-owned resources (the private service lane, the
        cached streamed engines, the materialized store's prefetch thread
        and temp directory); stores passed in by the caller stay open."""
        if self._service is not None:
            self._service.close()       # joins the lane — no walk in flight
            self._service = None
        for eng in self._engines.values():
            eng.close(close_store=False)
        self._engines.clear()
        if self._owns_store and self._store is not None:
            self._store.close()
            self._store = None
            self._owns_store = False
        if self._tmp_store_root is not None:
            import shutil
            shutil.rmtree(self._tmp_store_root, ignore_errors=True)
            self._tmp_store_root = None
        if self._owns_runtime:
            self.runtime.close()        # reap persistent transport workers

    def __enter__(self) -> "SamplingSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
