"""Remote dispatch: serialize a `SamplerConfig`, ship it through a runtime.

The ``remote`` backend does not walk the chain itself — it packages the
session's request (config + store location + batch size + PRNG key) into a
JSON-serializable *payload* and hands it to
:meth:`repro.api.runtime.ClusterRuntime.submit`:

* :class:`~repro.api.runtime.LocalRuntime` executes the payload in-process
  (the loopback transport — zero infrastructure, same serialization
  boundary, so the dispatch path is exercised by every tier-1 run);
* :class:`RemoteRuntime` (registered as ``runtime="remote"``) dispatches
  to a **persistent worker interpreter** over the framed-pipe RPC of
  ``repro.runtime.transport``: the worker is spawned once, stays alive
  across submits (warm jit cache, cached worker-side sessions), streams
  each batch result back, and is reaped when the runtime closes.  Nothing
  but the payload crosses — the same isolation a real RPC/queue transport
  to another machine would give.  ``RemoteRuntime(persistent=False)``
  keeps the old one-subprocess-per-batch behaviour as a measurable
  baseline (``benchmarks/bench_fleet.py``).

Either way the worker resolves the *inner* config against its own
local runtime (``runtime="local"``, ``backend=AUTO`` → streamed from the
store path), so remote samples are bit-identical to a local streamed walk
for the same seed — the §4.1 contract extends across the dispatch
boundary and is asserted in ``tests/test_api.py``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import tempfile
from typing import Optional

import numpy as np

from repro.api.runtime import ClusterRuntime, register_runtime

_DTYPE_FIELDS = ("compute_dtype", "wire_dtype")


def _dtype_name(dt) -> Optional[str]:
    return None if dt is None else np.dtype(dt).name


def _dtype_from_name(name: Optional[str]):
    # by-name lookup through jnp attributes: numpy's registry does not know
    # 'bfloat16' but jnp.bfloat16 (ml_dtypes) does
    import jax.numpy as jnp
    return None if name is None else getattr(jnp, name)


def config_to_dict(config) -> dict:
    """``SamplerConfig`` → a JSON-serializable dict (dtypes by name, the
    perfmodel ``Hardware`` by its fields, runtime by name).

    Field-by-field rather than ``dataclasses.asdict`` — the runtime field
    may hold a live :class:`ClusterRuntime` whose locks/queues must not be
    deep-copied."""
    out = {f.name: getattr(config, f.name)
           for f in dataclasses.fields(config)}
    for f in _DTYPE_FIELDS:
        out[f] = _dtype_name(out[f])
    rt = out.get("runtime")
    out["runtime"] = rt if isinstance(rt, (str, type(None))) else rt.name
    out["hardware"] = dataclasses.asdict(config.hardware)
    if out.get("chi_profile") is not None:
        out["chi_profile"] = [int(c) for c in out["chi_profile"]]
    if out.get("clamp") is not None:
        # canonical pair-list form: json would coerce the tuples anyway,
        # but an explicit shape keeps payload_cell's sorted dump stable
        # (the worker-side SamplerConfig re-normalizes on construction)
        out["clamp"] = [[int(s), list(o) if isinstance(o, tuple) else int(o)]
                        for s, o in out["clamp"]]
    return out


def config_from_dict(d: dict):
    """Inverse of :func:`config_to_dict`."""
    from repro.api.config import SamplerConfig
    from repro.core.perfmodel import Hardware
    d = dict(d)
    for f in _DTYPE_FIELDS:
        d[f] = _dtype_from_name(d.get(f))
    d["hardware"] = Hardware(**d["hardware"])
    if d.get("chi_profile") is not None:
        d["chi_profile"] = tuple(int(c) for c in d["chi_profile"])
    return SamplerConfig(**d)


def build_payload(config, store, n_samples: int, key, job=None) -> dict:
    """The unit of dispatch: one JOB BATCH, as plain JSON.

    Everything a worker needs to reproduce one macro batch bit-exactly:
    the session config, the store location, the batch size, the *job base
    key*, and (``job`` — a ``service.JobBatch``) the batch's identity
    within its job.  The worker derives the batch key itself via
    ``service.batch_key(key, batch_id, n_batches)`` — identical arithmetic
    to the local path, so a service may scatter one job's batches over
    many workers and reassemble a bit-identical result.  ``job=None``
    degrades to the v1 whole-run payload (a 1-batch job in disguise).

    The inner config re-resolves on the worker: ``backend=AUTO`` picks the
    streamed data plane from the store path, ``runtime="local"`` because
    the worker IS the remote process.  Γ itself never rides the payload —
    the store location does (shared filesystem / object store in a real
    deployment).
    """
    import jax

    from repro.api.runtime import AUTO
    inner = dataclasses.replace(config, backend=AUTO, runtime="local",
                                store_root=None, checkpoint_dir=None)
    out = {
        "version": 2,
        "config": config_to_dict(inner),
        "store_root": str(store.root),
        "storage_dtype": np.dtype(store.storage_dtype).name,
        "compute_dtype": np.dtype(store.compute_dtype).name,
        "n_samples": int(n_samples),
        "key_data": np.asarray(jax.random.key_data(key)).tolist(),
        "enable_x64": bool(jax.config.jax_enable_x64),
    }
    if job is not None:
        out["job"] = {"job_id": int(job.job_id),
                      "batch_id": int(job.batch_id),
                      "n_batches": int(job.n_batches)}
    return out


class _CachedSession:
    """A worker-held (store, session) pair — one per payload cell, kept
    open across batches so repeated batches of a job reuse one engine and
    jit cache (the point of a persistent worker)."""

    def __init__(self, store, session):
        self.store = store
        self.session = session

    def close(self) -> None:
        self.session.close()
        self.store.close()


def payload_cell(payload: dict) -> tuple:
    """The worker-side session-coalescing identity of a payload — the
    mirror of ``SamplingService._coalesce_session``'s (source, config)
    cell, in serialized form."""
    return (payload["store_root"], payload["storage_dtype"],
            payload["compute_dtype"],
            json.dumps(payload["config"], sort_keys=True))


def execute_payload(payload: dict, cache: Optional[dict] = None
                    ) -> np.ndarray:
    """Run one payload to completion — the worker half of the dispatch.

    Called in-process by ``LocalRuntime.submit``, as ``__main__`` by the
    one-shot baseline worker, and per batch frame by the persistent
    ``repro.runtime.transport`` worker loop — the latter passes ``cache``
    (a dict it owns and closes on shutdown) so sessions persist across
    batches.  Accepts v1 (whole-run) and v2 (job-batch) payloads; a v2
    payload's ``job`` entry selects the batch key exactly as the local
    scheduler would."""
    import jax

    version = int(payload.get("version", 1))
    if version not in (1, 2):
        raise ValueError(f"unknown payload version {version}")
    if payload.get("enable_x64"):
        jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp

    from repro.api.service import batch_key
    from repro.api.session import SamplingSession
    from repro.data.gamma_store import GammaStore

    config = config_from_dict(payload["config"])
    key = jax.random.wrap_key_data(
        jnp.asarray(payload["key_data"], dtype=jnp.uint32))
    job = payload.get("job")
    if job is not None:
        key = batch_key(key, int(job["batch_id"]), int(job["n_batches"]))
    if cache is None:
        with GammaStore(
                payload["store_root"],
                storage_dtype=_dtype_from_name(payload["storage_dtype"]),
                compute_dtype=_dtype_from_name(payload["compute_dtype"])
                ) as store:
            with SamplingSession(store, config) as session:
                return session.sample(payload["n_samples"], key)
    tok = payload_cell(payload)
    entry = cache.get(tok)
    if entry is None:
        store = GammaStore(
            payload["store_root"],
            storage_dtype=_dtype_from_name(payload["storage_dtype"]),
            compute_dtype=_dtype_from_name(payload["compute_dtype"]))
        entry = cache[tok] = _CachedSession(store,
                                            SamplingSession(store, config))
    return entry.session.sample(payload["n_samples"], key)


@register_runtime("remote")
class RemoteRuntime(ClusterRuntime):
    """Dispatch payloads to worker interpreters on this machine.

    ``persistent=True`` (the default): one long-lived worker process
    (``repro.runtime.transport``) is spawned on first :meth:`submit`, kept
    alive across submits — its jit cache and worker-side sessions stay
    warm, so batch k pays dispatch + compute, not interpreter + jax import
    + recompile — and reaped by :meth:`close` (sessions close runtimes
    they resolved themselves).  A worker that died is respawned
    transparently on the next submit; the failed submit raises
    ``transport.TransportError`` so callers requeue the (idempotent)
    batch.

    ``persistent=False`` is PR 5's behaviour — one fresh
    ``python -m repro.api.remote`` per submit — kept as the measurable
    baseline for ``benchmarks/bench_fleet.py``.

    Either way the subprocess boundary enforces that only the serialized
    payload crosses, exactly what an RPC transport to another machine
    would guarantee.  Point :attr:`python` / :attr:`env` at a container or
    remote-exec shim to move the worker off-host; neither the payload
    schema nor the frame protocol changes.
    """
    name = "remote"

    def __init__(self, python: Optional[str] = None,
                 env: Optional[dict] = None, timeout: float = 600.0,
                 persistent: bool = True):
        self.python = python or sys.executable
        self.env = env
        self.timeout = timeout
        self.persistent = persistent
        self._worker = None
        self._dispatch_bytes = 0
        self._dispatches = 0

    def io_counters(self) -> dict:
        out = super().io_counters()
        out.update(dispatch_bytes=self._dispatch_bytes,
                   dispatches=self._dispatches,
                   persistent_worker=bool(self._worker is not None
                                          and self._worker.alive))
        return out

    def submit(self, payload: dict) -> np.ndarray:
        blob = json.dumps(payload).encode()
        self._dispatch_bytes += len(blob)
        self._dispatches += 1
        if not self.persistent:
            return self._submit_oneshot(blob)
        from repro.runtime.transport import WorkerProcess
        if self._worker is None or not self._worker.alive:
            self._worker = WorkerProcess("remote-0", python=self.python,
                                         env=self.env, timeout=self.timeout)
        return self._worker.call(payload)

    def close(self) -> None:
        if self._worker is not None:
            self._worker.close()
            self._worker = None

    def _submit_oneshot(self, blob: bytes) -> np.ndarray:
        """The PR 5 baseline: a fresh interpreter per batch, serially."""
        env = dict(os.environ if self.env is None else self.env)
        src_root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        env["PYTHONPATH"] = src_root + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        with tempfile.TemporaryDirectory(prefix="fastmps_remote_") as tmp:
            payload_path = os.path.join(tmp, "payload.json")
            out_path = os.path.join(tmp, "samples.npy")
            with open(payload_path, "wb") as f:
                f.write(blob)
            proc = subprocess.run(
                [self.python, "-m", "repro.api.remote", payload_path,
                 out_path],
                env=env, capture_output=True, text=True,
                timeout=self.timeout)
            if proc.returncode != 0:
                raise RuntimeError(
                    f"remote worker failed (rc={proc.returncode}):\n"
                    f"{proc.stderr[-2000:]}")
            return np.load(out_path)


def _worker_main(argv: list[str]) -> int:
    payload_path, out_path = argv
    with open(payload_path) as f:
        payload = json.load(f)
    np.save(out_path, execute_payload(payload))
    return 0


if __name__ == "__main__":
    sys.exit(_worker_main(sys.argv[1:]))
