"""Data-plane registry for :class:`repro.api.SamplingSession`.

Execution is the composition of two orthogonal axes:

* a **data plane** (this registry): how a fully-resolved
  :class:`SessionPlan` walks the chain —

  - ``inmem``    — the whole stacked Γ is a device operand; routes to the
    ``core/sampler`` scan (scheme ``seq``), the ``core/parallel`` segment
    runner (``dp``/``tp_*``), the [19] pipeline, ``dynamic_bond``'s staged
    scans (seq + χ-profile, micro-batched or not), or a χ-stage loop over
    the segment runner (dp/tp + χ-profile);
  - ``streamed`` — the ``engine.StreamingEngine`` walks the chain in
    device-budgeted segments from a :class:`GammaStore` with
    double-buffered prefetch, composing every one of the above levels plus
    per-segment checkpointing and mid-chain resume;
  - ``remote``   — no local walk at all: the request is serialized and
    dispatched through the runtime (``repro.api.remote``);

* a **cluster runtime** (``repro.api.runtime``): where the participating
  processes live and how Γ bytes move between them — ``local``,
  ``multihost`` (paper §3.1 root-reads-then-broadcasts, streamed data
  plane only), ``remote``.

A (data_plane × runtime) cell is therefore *config*, not a class:
``SamplerConfig(backend="streamed", runtime="multihost")`` is the paper's
multi-host broadcast run.  Adding a data plane is a registry entry::

    @register_backend("my_backend")
    class MyBackend(Backend):
        name = "my_backend"
        def sample(self, req: SampleRequest) -> np.ndarray: ...

— sessions pick it up via ``SamplerConfig(backend="my_backend")``; nothing
in the session/driver layer changes.  Runtimes register the same way
(``repro.api.runtime.register_runtime``).

Every cell honours the seed-consistency contract (paper §4.1): for one
seed, every supported (data_plane × runtime × scheme) cell emits
**bit-identical** samples — asserted in ``tests/test_api.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import numpy as np

from repro.api.config import SessionPlan

_REGISTRY: dict[str, "Backend"] = {}


def register_backend(name: str) -> Callable[[type], type]:
    """Class decorator: instantiate and register a backend under ``name``."""
    def deco(cls: type) -> type:
        _REGISTRY[name] = cls()
        return cls
    return deco


def get_backend(name: str) -> "Backend":
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"no backend {name!r} registered; "
                         f"have {sorted(_REGISTRY)}") from None


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


@dataclasses.dataclass
class SampleRequest:
    """Everything a backend needs for one ``sample()`` execution.

    ``mps`` / ``store`` are zero-arg callables so a backend only pays the
    materialization it actually uses (a streamed session never loads the
    full chain; an in-memory session never writes a store).  ``runtime`` is
    the session's resolved :class:`~repro.api.runtime.ClusterRuntime`;
    ``config`` the original session-level config (what the ``remote`` data
    plane serializes and dispatches).
    """
    plan: SessionPlan
    n_samples: int
    key: jax.Array
    mesh: object
    mps: Callable[[], object]
    store: Callable[[], object]
    runtime: object = None
    config: object = None
    resume: bool = False
    checkpoint_dir: Optional[str] = None
    stop_after_segments: Optional[int] = None
    stats: dict = dataclasses.field(default_factory=dict)
    # service-layer extensions: the job-batch identity this request executes
    # (``repro.api.service.JobBatch`` — the remote data plane dispatches it
    # as the payload unit), whether the streamed engine should gang-schedule
    # (prefetch the next batch's first segment behind this batch's tail
    # compute), and the session's per-plan engine cache (one compilation and
    # one prefetch pool across all batches of a coalesced plan)
    job: object = None
    pipeline: bool = False
    engines: Optional[dict] = None


class Backend:
    """One execution strategy for a resolved :class:`SessionPlan`."""
    name = "abstract"

    def sample(self, req: SampleRequest) -> np.ndarray:
        raise NotImplementedError


def _warm_kernel_autotuner(plan: SessionPlan, n_samples: int, chi: int,
                           d: int, dtype) -> None:
    """Seed the kernel autotuner for every dispatched shape the walk will
    trace.  The timed TPU sweep cannot run inside a jit trace, so the data
    planes call this *before* compiling; off-TPU it just records the
    heuristic block table (no compilation, microseconds).

    seq/dp walks hit the fused ``site_step`` at the (per-chunk, χ-bucket)
    shapes; the TP schedules instead hit the bond-sharded
    ``contract_measure``/``measure``/``collapse`` stages, whose χ/p₂
    operand shapes are warmed per χ bucket too (``warm_tp_stages``)."""
    if plan.kernels != "pallas":
        return
    from repro.kernels.site_impls import warm_site_step, warm_tp_stages

    p1 = plan.p1 if plan.scheme != "seq" else 1
    n_chunk = plan.micro_batch or (n_samples // max(1, p1))
    chis = ({chi_s for _, _, chi_s in plan.stages}
            if plan.stages is not None else {chi})
    for chi_s in sorted(chis):
        if plan.scheme in ("tp_single", "tp_double"):
            if plan.semantics != "linear":
                continue            # born TP cells stay XLA by design
            warm_tp_stages(
                n_chunk, chi_s, d, dtype, p2=plan.p2, scheme=plan.scheme,
                measure_first=(plan.pconfig is not None
                               and plan.pconfig.measure_first),
                compute_dtype=plan.sampler_config.compute_dtype)
        else:
            warm_site_step(n_chunk, chi_s, d, dtype,
                           semantics=plan.semantics,
                           scaling=plan.sampler_config.scaling,
                           compute_dtype=plan.sampler_config.compute_dtype)


@register_backend("inmem")
class InMemBackend(Backend):
    """Whole-chain-on-device execution (paper §3.1–§3.2 in-memory paths)."""
    name = "inmem"

    def sample(self, req: SampleRequest) -> np.ndarray:
        from repro.core import dynamic_bond as DB
        from repro.core import parallel as PP
        from repro.core import sampler as S
        from repro.core.mps import MPS

        plan, n, key = req.plan, req.n_samples, req.key
        if req.resume:
            raise ValueError("mid-chain resume needs the streamed backend "
                             "(it owns the per-segment checkpoints)")
        mps = req.mps()
        cfg = plan.sampler_config
        _warm_kernel_autotuner(plan, n, mps.chi, mps.phys_dim,
                               mps.gammas.dtype)

        if plan.clamp is not None:
            return self._sample_clamped(req, mps, cfg)

        if plan.scheme == "seq":
            if plan.stages is not None:
                prof = np.asarray(plan.chi_profile)
                if plan.micro_batch is not None:
                    out = DB.sample_staged_batched(mps, prof, n, key,
                                                   plan.micro_batch, cfg)
                else:
                    out = DB.sample_staged(mps, prof, n, key, cfg)
            elif plan.micro_batch is not None:
                out = S.sample_batched(mps, n, key, plan.micro_batch, cfg)
            else:
                out = S.sample(mps, n, key, cfg)
            return np.asarray(out)

        if plan.scheme == "baseline19":
            return np.asarray(PP._baseline19_sample(req.mesh, mps, n, key,
                                                    cfg))

        if plan.stages is None:
            return np.asarray(PP._multilevel_sample(req.mesh, mps, n, key,
                                                    plan.pconfig, cfg))

        # dynamic χ under DP/TP: one segment-runner call per χ-stage, the
        # environment sliced/padded at stage boundaries exactly as
        # ``dynamic_bond.sample_staged`` does (shared ``fit_env``)
        env = PP.segment_env_init(n, plan.stages[0][2], mps.gammas.dtype)
        log_scale = None
        blocks = []
        for s0, s1, chi_s in plan.stages:
            seg = MPS(mps.gammas[s0:s1, :chi_s, :chi_s, :],
                      mps.lambdas[s0:s1, :chi_s], mps.semantics)
            env = DB.fit_env(env, chi_s)
            samples, env, log_scale = PP.sample_segment(
                req.mesh, seg, env, key, s0, plan.pconfig, cfg,
                log_scale=log_scale)
            blocks.append(np.asarray(samples))
        return np.concatenate(blocks, axis=0).T.astype(np.int32)

    def _sample_clamped(self, req: SampleRequest, mps, cfg) -> np.ndarray:
        """Conditional sampling (``plan.clamp``, repro.workloads): one
        χ-stage loop over the clamped segment runner, for every scheme.

        seq runs the clamped in-memory segment; dp the clamped shard_map
        segment; tp_* route through the dp walk over the mesh's non-model
        axes (``core.clamped.dp_equivalent_pconfig`` — §4.1 makes every
        schedule draw-identical per seed, so a clamped tp cell would emit
        the same bits).  The per-sample ``log_prob`` lands in
        ``req.stats`` → ``session.stats``.
        """
        from repro.core import clamped as CL
        from repro.core import dynamic_bond as DB
        from repro.core import parallel as PP
        from repro.core.mps import MPS
        from repro.workloads.clamp import clamp_map, segment_clamp_arrays

        plan, n, key = req.plan, req.n_samples, req.key
        cmap = clamp_map(plan.clamp)
        pconf = (CL.dp_equivalent_pconfig(plan.pconfig)
                 if plan.pconfig is not None else None)
        stages = plan.stages or ((0, mps.n_sites, mps.chi),)
        env = PP.segment_env_init(n, stages[0][2], mps.gammas.dtype)
        log_scale = log_prob = None
        blocks = []
        for s0, s1, chi_s in stages:
            seg = MPS(mps.gammas[s0:s1, :chi_s, :chi_s, :],
                      mps.lambdas[s0:s1, :chi_s], mps.semantics)
            env = DB.fit_env(env, chi_s)
            mask, vals = segment_clamp_arrays(cmap, s0, s1 - s0, n)
            if pconf is None:
                samples, env, log_scale, log_prob = CL.clamped_segment(
                    seg.gammas, seg.lambdas, env, key, s0, mask, vals, cfg,
                    log_scale=log_scale, log_prob=log_prob,
                    micro_batch=plan.micro_batch)
            else:
                samples, env, log_scale, log_prob = CL.sample_segment_clamped(
                    req.mesh, seg, env, key, s0, mask, vals, pconf, cfg,
                    log_scale=log_scale, log_prob=log_prob)
            blocks.append(np.asarray(samples))
        req.stats["log_prob"] = np.asarray(log_prob)
        return np.concatenate(blocks, axis=0).T.astype(np.int32)


@register_backend("streamed")
class StreamedBackend(Backend):
    """Segment-streamed execution through :class:`engine.StreamingEngine`."""
    name = "streamed"

    def sample(self, req: SampleRequest) -> np.ndarray:
        from repro.engine.streaming import StreamingEngine, StreamPlan

        plan = req.plan
        store = req.store()
        shape = store.meta(0)
        _warm_kernel_autotuner(plan, req.n_samples, shape[0], shape[2],
                               store.compute_dtype)
        engine_scheme = "inmem" if plan.scheme == "seq" else plan.scheme
        shard = None
        if plan.shard_block:
            # host count binds HERE, to the executing runtime — the same
            # plan dispatched to a lone remote worker builds the degenerate
            # 1-host map and walks locally, bit-identical
            from repro.shard.shardmap import ShardMap
            n_hosts = (req.runtime.process_count
                       if req.runtime is not None else 1)
            shard = ShardMap(n_sites=store.n_sites, n_hosts=max(1, n_hosts),
                             block=plan.shard_block)

        def build() -> StreamingEngine:
            return StreamingEngine(
                store, semantics=plan.semantics, config=plan.sampler_config,
                plan=StreamPlan(segment_len=plan.segment_len,
                                scheme=engine_scheme,
                                micro_batch=plan.micro_batch,
                                checkpoint_every=plan.checkpoint_every),
                mesh=req.mesh if engine_scheme != "inmem" else None,
                pconfig=plan.pconfig,
                chi_profile=plan.chi_profile,
                runtime=req.runtime,
                shard=shard,
                clamp=plan.clamp)

        if req.engines is None:         # direct Backend use: walk and release
            eng = build()
            try:
                out = eng.sample(req.n_samples, req.key, resume=req.resume,
                                 stop_after_segments=req.stop_after_segments,
                                 checkpoint_dir=req.checkpoint_dir)
                req.stats.update(eng.stats)
                return out
            finally:
                # the store may be session-owned and serve further calls
                eng.close(close_store=False)

        # session path: ONE engine per engine-identity, living as long as
        # the session — repeated macro batches reuse its jit cache and
        # prefetch pool (which is what lets the service gang-schedule batch
        # b+1's first-segment fetch/broadcast behind batch b's tail
        # compute).  The key is the engine's CONSTRUCTOR identity, not the
        # whole plan: n_samples must not fragment the cache, or jobs that
        # differ only in batch size would each pin an engine (and its pool
        # thread) until session close
        eng_key = (engine_scheme, plan.semantics, plan.segment_len,
                   plan.micro_batch, plan.chi_profile, plan.checkpoint_every,
                   plan.sampler_config, plan.pconfig, plan.shard_block,
                   plan.clamp)
        eng = req.engines.get(eng_key)
        if eng is None:
            new = build()
            eng = req.engines.setdefault(eng_key, new)  # lose the build race
            if eng is not new:
                new.close(close_store=False)
        # stats snapshot under the engine's walk lock: a concurrent lane's
        # next walk resets eng.stats in place
        out, stats = eng.sample_with_stats(
            req.n_samples, req.key, resume=req.resume,
            stop_after_segments=req.stop_after_segments,
            checkpoint_dir=req.checkpoint_dir, pipeline=req.pipeline)
        req.stats.update(stats)
        return out


@register_backend("remote")
class RemoteBackend(Backend):
    """Dispatch the serialized request through the runtime (no local walk).

    The payload (``repro.api.remote``) carries the session config, the
    store location, the batch size, and the PRNG key; the runtime's
    ``submit`` runs it wherever its workers live — in-process for
    ``LocalRuntime`` (loopback), a fresh worker interpreter for
    ``RemoteRuntime``.  The worker resolves the inner config locally and
    its streamed walk is bit-identical to a local one (§4.1 across the
    dispatch boundary).
    """
    name = "remote"

    def sample(self, req: SampleRequest) -> np.ndarray:
        from repro.api.remote import build_payload

        if req.resume:
            raise ValueError("resume is local to the worker's checkpoint "
                             "dir — re-dispatch the batch instead (macro "
                             "batches are idempotent work items)")
        if req.checkpoint_dir is not None:
            raise ValueError("backend='remote' does not ship a "
                             "checkpoint_dir (see resolve_plan) — remote "
                             "fault tolerance is per-macro-batch")
        # the store is the hand-off medium: an MPS source is materialized
        # once (identity dtype) and only its *location* rides the payload.
        # The dispatch unit is the JOB BATCH: req.key is the job's base key
        # and req.job its (job_id, batch_id, n_batches) identity — the
        # worker folds the batch key itself (service.batch_key), so a
        # service can fan a job's batches over many workers and every batch
        # stays bit-identical to its local counterpart.
        store = req.store()
        payload = build_payload(req.config, store, req.n_samples, req.key,
                                job=req.job)
        # counters are monotonic on the runtime — stats report this call's
        # delta, matching the streamed engine's per-walk scoping
        before = dict(req.runtime.io_counters())
        out = req.runtime.submit(payload)
        req.stats.update({f"runtime_{k}": v - before.get(k, 0)
                          for k, v in req.runtime.io_counters().items()})
        return np.asarray(out)
