"""Config schema + resolution for the unified sampling front door.

:class:`SamplerConfig` is the *session-level* schema: one frozen dataclass
describing workload semantics, placement scheme, precision, χ-profile, micro
batching, and streaming/checkpoint options.  Fields set to :data:`AUTO` are
resolved against the perfmodel planner (``engine/planner`` + ``core/perfmodel``)
and the session's source/mesh into a concrete :class:`SessionPlan` — the
fully-resolved record a backend executes and ``session.plan()`` returns.

(The identically-named ``repro.core.sampler.SamplerConfig`` is the *kernel*
config — semantics/scaling/compute dtype of one chain scan.  Resolution
builds it from this schema; applications only touch the session-level one.)

Schema summary (see also examples/README.md):

======================  =====================================================
field                   meaning
======================  =====================================================
``semantics``           "linear" | "born" | AUTO (taken from the source MPS)
``scheme``              "seq" | "dp" | "tp_single" | "tp_double" |
                        "baseline19" | AUTO (planner: Eq. 7 TP selector over
                        the mesh's p₁×p₂)
``backend``             the *data plane*: "inmem" | "streamed" | "remote" |
                        AUTO (streamed iff the source is a ``GammaStore`` /
                        store path; remote iff the runtime is remote)
``runtime``             the *cluster runtime*: "local" | "multihost" |
                        "remote" | a ``ClusterRuntime`` instance | AUTO
                        (local on one process).  Orthogonal to ``backend``:
                        ``streamed × multihost`` is the paper's §3.1
                        process-0-reads-then-broadcasts cell
``scaling``             §3.3 environment rescale: "none"|"global"|"per_sample"
``kernels``             site-step kernel dispatch: "pallas" (fused VMEM-
                        resident pipeline, ``kernels/dispatch.py``) | "xla" |
                        AUTO (pallas on a TPU backend, xla elsewhere)
``compute_dtype``       mixed-precision GEMM inputs (e.g. ``jnp.bfloat16``)
``wire_dtype``          §3.3.2-on-the-wire cast for TP collectives
``measure_first``       tp-3 measure-first reformulation (linear semantics)
``micro_batch``         N₂ *per data shard* (int), AUTO (memory-model pick),
                        or None (whole batch in one chunk)
``chi_profile``         per-site bucketed χ tuple (§3.4.2) or None (fixed χ)
``segment_len``         streamed-backend sites per device segment, or AUTO
                        (largest L whose two buffers fit the device budget)
``shard``               chain sharding (``repro.shard``): None (off — the
                        §3.1 broadcast plane), an int block size in sites
                        (block-cyclic site→host ownership; must be a whole
                        number of segments), or AUTO (one segment per
                        block).  Streamed backend only; composes with
                        DP-over-samples and dynamic χ
``clamp``               conditional sampling (``repro.workloads``): a
                        ``{site: outcome}`` / ``{site: per-sample array}``
                        mapping fixing outcomes at a subset of sites; the
                        walk forces those outcomes into the collapse path
                        and returns the clamped branch's Born weight as a
                        per-sample ``log_prob`` in ``session.stats``.
                        ``None``/``{}`` = unclamped (bit-identical to the
                        plain sampler)
``store_root``          where a streamed session materializes Γ when built
                        from an in-memory MPS (default: temp dir)
``checkpoint_dir``      per-segment checkpoint directory (streamed backend)
``checkpoint_every``    segments between checkpoints (0 = off)
``hardware``            perfmodel :class:`Hardware` the AUTO fields plan for
``device_budget``       device memory budget override in bytes
======================  =====================================================
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Union

import numpy as np

from repro.api.runtime import ClusterRuntime, resolve_runtime
from repro.core.dynamic_bond import stages_from_profile
from repro.core.parallel import ParallelConfig
from repro.core.perfmodel import (Hardware, TPU_V5E, Workload,
                                  choose_tp_scheme)
from repro.core.sampler import SamplerConfig as CoreSamplerConfig
from repro.workloads.clamp import normalize_clamp, validate_clamp

AUTO = "auto"

_SCHEMES = ("seq", "dp", "tp_single", "tp_double", "baseline19")


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    """Session-level sampling configuration (see module docstring)."""
    # workload semantics / numerics
    semantics: str = AUTO
    scaling: str = "per_sample"
    kernels: str = AUTO                # site-step dispatch: pallas | xla
    compute_dtype: Optional[Any] = None
    wire_dtype: Optional[Any] = None
    measure_first: bool = False
    # placement: data plane (backend) × cluster runtime — orthogonal axes
    scheme: str = AUTO
    backend: str = AUTO
    runtime: Union[str, ClusterRuntime] = AUTO
    # batching (paper N₂; per data shard)
    micro_batch: Union[int, str, None] = None
    # dynamic bond dimensions (paper §3.4.2): bucketed per-site χ
    chi_profile: Optional[tuple[int, ...]] = None
    # streaming backend
    segment_len: Union[int, str] = AUTO
    # chain sharding (block-cyclic Γ distribution, repro.shard): None = the
    # §3.1 broadcast plane; int = sites per ownership block; AUTO = one
    # segment per block
    shard: Union[int, str, None] = None
    # conditional sampling (repro.workloads): {site: outcome} or
    # {site: per-sample outcomes}; normalized at construction to the
    # canonical hashable spec (service coalescing cells and streamed
    # engine keys contain this config).  None/{} = unclamped.
    clamp: Optional[Any] = None
    store_root: Optional[str] = None
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 1
    # planner inputs for the AUTO fields
    hardware: Hardware = TPU_V5E
    device_budget: Optional[float] = None

    def __post_init__(self):
        # malformed specs raise ValueError here — the gateway turns that
        # into a clean 400 ("invalid config: ...") via config_from_dict
        object.__setattr__(self, "clamp", normalize_clamp(self.clamp))


@dataclasses.dataclass(frozen=True)
class SessionPlan:
    """Fully-resolved execution record for one ``session.sample(n, key)``."""
    backend: str                       # data plane: "inmem" | "streamed" | ...
    runtime: str                       # cluster runtime name: "local" | ...
    scheme: str                        # "seq" | "dp" | "tp_single" | ...
    semantics: str
    kernels: str                       # resolved dispatch: "pallas" | "xla"
    n_samples: int
    p1: int                            # data-parallel shards
    p2: int                            # tensor-parallel workers per group
    micro_batch: Optional[int]         # N₂ per data shard (resolved)
    segment_len: Optional[int]         # streamed backend only
    chi_profile: Optional[tuple[int, ...]]
    stages: Optional[tuple[tuple[int, int, int], ...]]   # (start, stop, χ)
    checkpoint_every: int
    sampler_config: CoreSamplerConfig  # the kernel-level config
    pconfig: Optional[ParallelConfig]  # dp/tp placement, None for seq
    # chain sharding: sites per block-cyclic ownership block (repro.shard),
    # None for the broadcast plane.  The host count is the RUNTIME's
    # process count at execution time, so the same plan serializes cleanly
    # to a remote worker (which runs the degenerate 1-host shard).
    shard_block: Optional[int] = None
    # conditional sampling: the normalized clamp spec (repro.workloads),
    # range-validated against this plan's chain/batch; None = unclamped —
    # a None-clamp plan executes the UNCHANGED unclamped code paths, so
    # empty-clamp bit-identity holds by construction.
    clamp: Optional[tuple] = None

    @property
    def cell(self) -> tuple[str, str, str, str, str]:
        """The plan's config-cell identity (backend × runtime × scheme ×
        semantics × kernels) — what the service layer coalesces jobs on:
        two plans in one cell share compilation given equal shapes."""
        return (self.backend, self.runtime, self.scheme, self.semantics,
                self.kernels)


def _mesh_sizes(mesh) -> tuple[int, int]:
    if mesh is None:
        return 1, 1
    shape = dict(mesh.shape)
    p2 = shape.get("model", 1)
    p1 = 1
    for ax, size in shape.items():
        if ax != "model":
            p1 *= size
    return p1, p2


def _auto_micro_batch(n_local: int, chi: int, d: int, budget: float,
                      bytes_per_elt: int = 8) -> Optional[int]:
    """Eq. 3 memory-model pick: the largest divisor of the local batch whose
    unmeasured (N₂, χ, d) intermediate stays under ~10% of the budget."""
    target = max(1, int(0.1 * budget // (chi * d * bytes_per_elt)))
    if target >= n_local:
        return None                     # the whole shard fits — no chunking
    for k in range(target, 0, -1):
        if n_local % k == 0:
            return k
    return None


def resolve_plan(config: SamplerConfig, *, n_samples: int, n_sites: int,
                 chi: int, d: int, mesh=None, source_semantics=None,
                 backend_hint: str = "inmem", elt_bytes: int = 8,
                 runtime: Optional[ClusterRuntime] = None) -> SessionPlan:
    """Resolve every AUTO field of ``config`` into a :class:`SessionPlan`.

    Raises ``ValueError`` for contradictory requests (a parallel scheme with
    no mesh, a χ bucket that does not divide over p₂, an unsupported
    runtime × data-plane cell, ...) — the session surfaces these before any
    compilation happens.  ``runtime`` is the session's already-resolved
    :class:`ClusterRuntime`; ``None`` resolves ``config.runtime`` here.
    """
    from repro.api.backends import available_backends

    if runtime is None:
        runtime = resolve_runtime(config.runtime)
    backend = config.backend
    if backend == AUTO:
        # a remote runtime can only execute a dispatched payload — the
        # worker picks the data plane on its side
        backend = "remote" if runtime.name == "remote" else backend_hint
    if backend not in available_backends():
        raise ValueError(f"unknown backend {backend!r}; have "
                         f"{available_backends()} "
                         f"(registry: repro.api.register_backend)")

    # -- runtime × data-plane cell validation -------------------------------
    if runtime.process_count > 1 and backend != "streamed":
        raise ValueError(
            f"runtime {runtime.name!r} spans {runtime.process_count} "
            f"processes — the §3.1 Γ broadcast needs the 'streamed' data "
            f"plane (got backend={backend!r})")
    if runtime.name == "remote" and backend != "remote":
        raise ValueError(
            f"a remote runtime dispatches serialized configs — use "
            f"backend='remote' (or AUTO), not {backend!r}")
    if backend == "remote":
        if config.scheme not in (AUTO, "seq"):
            raise ValueError(
                f"backend='remote' resolves placement on the worker — "
                f"scheme must stay AUTO/'seq' on the dispatching side "
                f"(got {config.scheme!r})")
        if mesh is not None:
            raise ValueError("backend='remote' takes no local mesh — the "
                             "worker builds its own from its runtime")
        if config.checkpoint_dir is not None:
            raise ValueError(
                "backend='remote' does not ship checkpoint_dir — the "
                "worker's checkpoints would be local to it and resume "
                "could not find them; rely on idempotent macro batches "
                "(run_queue) for remote fault tolerance")

    semantics = (config.semantics if config.semantics != AUTO
                 else (source_semantics or "linear"))

    # -- kernel dispatch (AUTO → pallas on TPU, xla elsewhere) --------------
    from repro.kernels.dispatch import resolve_kernels
    kernels = resolve_kernels(config.kernels)   # raises on unknown modes

    p1, p2 = _mesh_sizes(mesh)
    hw = config.hardware
    budget = config.device_budget if config.device_budget else hw.mem_capacity

    # -- scheme (Eq. 7 TP selector when the mesh has a model axis) ----------
    scheme = config.scheme
    w_probe = Workload(n_samples=n_samples, n_sites=n_sites, chi=chi, d=d,
                       macro_batch=n_samples,
                       micro_batch=max(1, n_samples // p1))
    if scheme == AUTO:
        if mesh is None or (p1 == 1 and p2 == 1):
            scheme = "seq"
        elif p2 > 1:
            scheme = "tp_" + choose_tp_scheme(w_probe, hw, p2)
        else:
            scheme = "dp"
    if scheme not in _SCHEMES:
        raise ValueError(f"unknown scheme {scheme!r}; have {_SCHEMES}")
    if scheme in ("dp", "tp_single", "tp_double", "baseline19") and mesh is None:
        raise ValueError(f"scheme {scheme!r} needs a mesh")
    if scheme == "baseline19" and backend != "inmem":
        raise ValueError("the [19] pipeline exists for comparison only and "
                         "has no streamed backend")
    if scheme in ("dp", "tp_single", "tp_double") and n_samples % p1 != 0:
        raise ValueError(f"n_samples={n_samples} must divide over the "
                         f"p₁={p1} data shards")
    if scheme in ("tp_single", "tp_double") and chi % p2 != 0:
        raise ValueError(f"χ={chi} does not divide over p₂={p2} "
                         f"tensor-parallel workers")
    n_local = n_samples // (p1 if scheme != "seq" else 1)

    # -- dynamic bond dimensions (§3.4.2) -----------------------------------
    chi_profile = config.chi_profile
    stages = None
    if chi_profile is not None:
        chi_profile = tuple(int(c) for c in chi_profile)
        if len(chi_profile) != n_sites:
            raise ValueError(f"chi_profile covers {len(chi_profile)} of "
                             f"{n_sites} sites")
        if max(chi_profile) > chi:
            raise ValueError(f"chi_profile exceeds the chain's χ "
                             f"({max(chi_profile)} > {chi})")
        if scheme == "baseline19":
            raise ValueError("dynamic χ does not compose with the [19] "
                             "pipeline baseline")
        stages = tuple((st.start, st.stop, st.chi) for st in
                       stages_from_profile(np.asarray(chi_profile)))
        if scheme in ("tp_single", "tp_double"):
            for s0, s1, chi_s in stages:
                if chi_s % p2 != 0:
                    raise ValueError(f"χ bucket {chi_s} does not divide over "
                                     f"p₂={p2} tensor-parallel workers")
        if scheme == "tp_double":
            for s0, s1, _ in stages:
                if s0 % 2 or s1 % 2:
                    raise ValueError(
                        "tp_double pairs sites (2j, 2j+1): χ-stage "
                        f"boundaries must be even (got [{s0}, {s1}))")

    # -- micro batching N₂ (per data shard) ---------------------------------
    micro = config.micro_batch
    micro_was_auto = micro == AUTO
    if micro_was_auto:
        micro = _auto_micro_batch(n_local, chi, d, budget,
                                  bytes_per_elt=elt_bytes)
        # AUTO must resolve to a *supported* value: the [19] pipeline is the
        # one cell micro batching does not compose with
        if scheme == "baseline19":
            micro = None
    if micro is not None:
        micro = int(micro)
        if micro <= 0 or n_local % micro != 0:
            raise ValueError(f"micro_batch={micro} must divide the local "
                             f"batch {n_local}")
        if micro == n_local and micro_was_auto:
            micro = None
    if micro is not None and scheme == "baseline19":
        raise ValueError("micro batching does not compose with the [19] "
                         "pipeline baseline")

    # -- streamed-backend segment length ------------------------------------
    segment_len = None
    if backend == "streamed":
        if config.segment_len == AUTO:
            from repro.engine.planner import plan_stream
            w = Workload(n_samples=n_samples, n_sites=n_sites, chi=chi, d=d,
                         macro_batch=n_samples,
                         micro_batch=(micro * p1 if micro else n_samples))
            segment_len = plan_stream(
                w, hw, p1=p1, p2=p2, compute_bytes=elt_bytes,
                device_budget=config.device_budget).segment_len
        else:
            segment_len = int(config.segment_len)
            if segment_len < 1:
                raise ValueError(f"segment_len must be ≥ 1, got {segment_len}")
        if scheme == "tp_double" and segment_len % 2:
            segment_len += 1            # pairs never straddle segments

    # -- chain sharding (block-cyclic Γ distribution, repro.shard) ----------
    shard_block = None
    if config.shard is not None:
        if backend == "remote":
            # rides the serialized config untouched; the WORKER resolves it
            # against its own runtime (a single worker runs the degenerate
            # 1-host shard, bit-identical by construction)
            pass
        elif backend != "streamed":
            # also covers the [19] pipeline baseline, which is inmem-only
            raise ValueError(
                f"chain sharding distributes the streamed Γ walk — it needs "
                f"backend='streamed', got {backend!r}")
        else:
            shard_block = (segment_len if config.shard == AUTO
                           else int(config.shard))
            if shard_block < 1:
                raise ValueError(f"shard block must be ≥ 1 site, got "
                                 f"{shard_block}")
            if shard_block % segment_len != 0:
                raise ValueError(
                    f"shard block ({shard_block} sites) must be a whole "
                    f"number of segments (segment_len={segment_len}) — a "
                    f"segment contracted on one host cannot straddle two "
                    f"owners")
            # prove single-ownership against the engine's REAL schedule
            # (χ-stages can split blocks in ways the uniform check misses)
            from repro.shard.shardmap import ShardMap, chain_segments
            smap = ShardMap(n_sites=n_sites,
                            n_hosts=max(1, runtime.process_count),
                            block=shard_block)
            smap.owners_for(chain_segments(n_sites, segment_len, stages))

    # -- conditional sampling (repro.workloads clamp) -----------------------
    clamp = config.clamp                # already normalized by __post_init__
    if clamp is not None:
        if backend == "remote":
            # rides the serialized config; the WORKER validates against the
            # store it opens (chain length / d are not known here)
            pass
        else:
            validate_clamp(clamp, n_sites=n_sites, d=d, n_samples=n_samples)
        if scheme == "baseline19":
            raise ValueError("clamped sampling does not compose with the "
                             "[19] pipeline baseline")

    pconfig = None
    if scheme in ("dp", "tp_single", "tp_double"):
        # shard the batch over EVERY non-model mesh axis ("pod" folds into
        # data parallel on multi-pod meshes) — must agree with the p₁ the
        # plan validated n_samples/micro_batch against
        data_axes = tuple(ax for ax in mesh.axis_names if ax != "model")
        pconfig = ParallelConfig(scheme=scheme, data_axes=data_axes,
                                 wire_dtype=config.wire_dtype,
                                 measure_first=config.measure_first,
                                 micro_batch=micro)
    sampler_config = CoreSamplerConfig(semantics=semantics,
                                       scaling=config.scaling,
                                       compute_dtype=config.compute_dtype,
                                       kernels=kernels)
    return SessionPlan(backend=backend, runtime=runtime.name, scheme=scheme,
                       semantics=semantics, kernels=kernels,
                       n_samples=n_samples, p1=p1, p2=p2, micro_batch=micro,
                       segment_len=segment_len, chi_profile=chi_profile,
                       stages=stages,
                       checkpoint_every=config.checkpoint_every,
                       sampler_config=sampler_config, pconfig=pconfig,
                       shard_block=shard_block, clamp=clamp)
