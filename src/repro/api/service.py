"""`SamplingService` — the async job front door over `SamplingSession`.

The paper's central property — every macro batch is an independent,
restart-exact unit of work (batch = f(seed, id)) — is exactly what a
serving system needs, so this module turns sampling into *jobs*:

    with api.SamplingService(workers=2) as svc:
        h = svc.submit(store_path, cfg, n_samples=4096,
                       key=jax.random.key(0), macro_batches=4)
        for batch_id, block in h.stream():      # blocks as they complete
            persist(batch_id, block)
        # or: samples = h.result()              # blocking concatenation

A job is decomposed into its N₁ macro batches and fed through an elastic
:class:`repro.runtime.elastic.WorkQueue` — ONE job/batch table that every
lane, local or remote, claims from.  A **lane** comes in two kinds:

* **thread lanes** (default): threads driving the session's data plane in
  this process — PR 5's behaviour;
* **fleet lanes** (``pool=``): each lane owns one *persistent worker
  process* in a :class:`repro.runtime.transport.WorkerPool`; a claimed
  batch is serialized as the v2 job-batch payload (``repro.api.remote``)
  and dispatched over the framed-pipe RPC, and the worker — alive across
  batches, warm jit cache and cached sessions — streams the block back.
  A transport fault (worker death, dropped result, deadline) is a *lane*
  fault, never a job fault: the batch requeues, the worker respawns, and
  the recomputation is bit-identical.

The queue's guarantees hold verbatim either way:

* batches rebalance on worker loss (:meth:`SamplingService.remove_worker`
  requeues the victim's in-flight batches; a late result from the removed
  worker is discarded by the queue's ownership check — the recomputation
  is bit-identical anyway),
* completed work is never recomputed,
* results are owner- and order-independent.

**Scheduling.**  Jobs are served in priority order (higher ``priority``
first, FIFO within a priority); requeued batches are re-offered before
fresh ones (``WorkQueue`` fairness).  Same-(source, config)-cell jobs
**coalesce onto one session** — one resolved plan, one jit cache, one
streamed engine — so a burst of small requests against one store never
recompiles.  Multi-batch streamed jobs run **gang-scheduled**: the engine
prefetches macro batch b+1's first Γ segment (local read or §3.1
broadcast) while batch b's tail still computes.

**Straggler mitigation** (``runtime/stragglers``): each job tracks an
EWMA of its batch completion times; when a lane finds nothing fresh to
claim, a batch whose owner has exceeded ``straggler_k × EWMA`` is
*reclaimed* and re-issued to the idle lane (Eq. 1's ``N·(max−mean)`` tail,
statistically removed).  The late original's completion is rejected by the
ownership check — idempotent batches make the duplicate harmless, and the
bits are identical whichever copy lands.

**Admission control.**  ``max_active_bytes`` caps the *modeled* resident
footprint (perfmodel Eq. 3 — plans already carry the FLOP/byte numbers)
of concurrently-running jobs: a burst of large jobs queues in priority
order instead of thrashing one device budget, with the backpressure
surfaced in :meth:`stats` (``admission``: queued vs admitted jobs, active
model bytes).  One job is always admitted, so a job larger than the
budget still runs — alone.

**Key schedule** (:func:`batch_key`): a single-batch job draws with the
job key itself — so ``SamplingSession.sample`` (reimplemented as a
one-job synchronous wrapper over this service) stays bit-identical to
every pre-service release; a k-batch job draws batch b with
``fold_in(key, b)`` — the ``run_queue`` schedule, so streamed blocks are
bit-identical per seed to one-shot ``session.sample`` calls.
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import threading
import time
from typing import Any, Iterable, Iterator, Optional, Union

import numpy as np

from repro.runtime.elastic import WorkQueue
from repro.runtime.faults import (KINDS, CrashLoopLane, DeadLetter, Fault,
                                  FaultReport, classify, dead_letter_kind)
from repro.runtime.stragglers import StragglerMitigator

# job lifecycle states (JobHandle.status())
PENDING, RUNNING, DONE, FAILED, CANCELLED = (
    "pending", "running", "done", "failed", "cancelled")


class JobCancelled(RuntimeError):
    """Raised by ``result()``/``stream()`` of a cancelled job."""


def batch_key(key, batch_id: int, n_batches: int):
    """The job → macro-batch PRNG schedule (one definition, used by the
    local execution path and by the remote worker decoding a job payload).

    A 1-batch job IS the one-shot call — its key passes through untouched,
    which is what keeps ``session.sample(n, key)`` bit-identical across
    the service redesign.  A k-batch job derives batch b's key as
    ``fold_in(key, b)``, the established macro-batch schedule
    (``run_queue``, ``launch/sample.py``), so batch = f(seed, id)."""
    import jax

    if n_batches == 1:
        return key
    return jax.random.fold_in(key, batch_id)


def batch_checkpoint_dir(root: str, batch_id: int) -> str:
    """The per-batch checkpoint subdirectory convention — ONE definition
    shared by the service scheduler and ``session.run_queue`` so their
    mid-chain restarts interoperate."""
    return os.path.join(root, f"batch_{batch_id:05d}")


def has_chain_checkpoint(ck_dir: str) -> bool:
    """Whether a per-batch checkpoint dir holds a resumable mid-chain
    state (the engine's ``site_*`` files)."""
    return any(f.startswith("site_") for f in os.listdir(ck_dir))


@dataclasses.dataclass(frozen=True)
class JobBatch:
    """Identity of one macro batch of one job — the unit a worker executes
    and (fleet lanes / ``backend="remote"``) the unit the transport
    dispatches (see ``repro.api.remote.build_payload``)."""
    job_id: int
    batch_id: int
    n_batches: int


@dataclasses.dataclass
class _Job:
    job_id: int
    session: Any                       # the (possibly coalesced) SamplingSession
    n_samples: int                     # total over all batches
    per_batch: int
    n_batches: int
    key: Any
    priority: int
    queue: WorkQueue
    straggler: StragglerMitigator
    skip: frozenset
    state: str = PENDING
    error: Optional[BaseException] = None
    # fault history (runtime/faults.Fault records) + the dead-letter record
    # set when bounded retries exhaust a poison batch
    faults: list = dataclasses.field(default_factory=list)
    dead_letter: Optional[dict] = None
    blocks: dict = dataclasses.field(default_factory=dict)
    batch_stats: dict = dataclasses.field(default_factory=dict)
    # perfmodel admission numbers (Eq. 3 resident bytes of one active
    # batch; total modeled compute seconds over the job's batches)
    model_bytes: float = 0.0
    model_compute_s: float = 0.0
    # single-batch session.sample passthroughs
    resume: bool = False
    checkpoint_dir: Optional[str] = None
    stop_after_segments: Optional[int] = None
    # multi-batch fault tolerance: per-batch checkpoint subdirs + auto-resume
    checkpoint_root: Optional[str] = None

    @property
    def expected(self) -> list[int]:
        return [b for b in range(self.n_batches) if b not in self.skip]


class JobHandle:
    """The caller's view of one submitted job."""

    def __init__(self, service: "SamplingService", job: _Job):
        self._service = service
        self._job = job

    @property
    def job_id(self) -> int:
        return self._job.job_id

    def status(self) -> str:
        """One of pending | running | done | failed | cancelled."""
        with self._service._cond:
            return self._job.state

    @property
    def progress(self) -> dict:
        """Snapshot: batch counts + the underlying ``WorkQueue.stats()`` +
        straggler/admission numbers."""
        with self._service._cond:
            out = self._job.queue.stats()
            out.update(state=self._job.state,
                       skipped=len(self._job.skip),
                       blocks=len(self._job.blocks),
                       faults=len(self._job.faults),
                       model_bytes=self._job.model_bytes,
                       model_compute_s=self._job.model_compute_s)
            out.update(self._job.straggler.stats())
            return out

    def fault_report(self) -> Optional[dict]:
        """Structured fault history of this job, or None when fault-free:
        the per-attempt :class:`~repro.runtime.faults.Fault` records, kind
        counts, and — when bounded retries exhausted a poison batch — the
        dead-letter record (``batch``/``attempts``/``kind``)."""
        with self._service._cond:
            job = self._job
            if not job.faults and job.dead_letter is None:
                return None
            return FaultReport(faults=list(job.faults),
                               dead_letter=job.dead_letter).to_dict()

    def cancel(self) -> bool:
        """Stop scheduling this job's remaining batches.  Returns whether
        the cancel landed (a finished/failed job reports False).  An
        in-flight batch is not interrupted; its result is discarded."""
        svc = self._service
        with svc._cond:
            if self._job.state in (DONE, FAILED, CANCELLED):
                return self._job.state == CANCELLED
            svc._finish(self._job, CANCELLED)
            svc._cond.notify_all()
            return True

    def stream(self, timeout: Optional[float] = None
               ) -> Iterator[tuple[int, np.ndarray]]:
        """Yield ``(batch_id, samples)`` per macro batch, in batch order, as
        batches complete.  The concatenation of the yielded blocks is
        bit-identical per seed to the one-shot path (see :func:`batch_key`).
        ``timeout`` is a per-batch deadline (a busy service notifies the
        condition constantly; the clock must not re-arm on every wake).
        Raises the job's error / :class:`JobCancelled` mid-iteration."""
        import time as _time

        svc = self._service
        job = self._job
        for b in job.expected:
            deadline = (None if timeout is None
                        else _time.monotonic() + timeout)
            with svc._cond:
                while b not in job.blocks:
                    if job.state == FAILED:
                        raise job.error
                    if job.state == CANCELLED:
                        raise JobCancelled(
                            f"job {job.job_id} cancelled after "
                            f"{len(job.blocks)}/{len(job.expected)} batches")
                    remaining = (None if deadline is None
                                 else deadline - _time.monotonic())
                    if remaining is not None and remaining <= 0:
                        raise TimeoutError(
                            f"job {job.job_id}: batch {b} not done within "
                            f"{timeout}s")
                    svc._cond.wait(timeout=remaining)
                block = job.blocks[b]
            yield b, block

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        """Block until the job finishes; returns the (N, M) concatenation
        of its macro-batch blocks in batch order."""
        blocks = [blk for _, blk in self.stream(timeout=timeout)]
        if not blocks:
            raise ValueError(f"job {self.job_id} has no batches to run "
                             f"(all {len(self._job.skip)} skipped)")
        return np.concatenate(blocks, axis=0)

    @property
    def stats(self) -> dict:
        """Per-batch engine/runtime statistics (batch_id → stats dict)."""
        with self._service._cond:
            return {b: dict(s) for b, s in self._job.batch_stats.items()}


class SamplingService:
    """Job scheduler over the session registries; see module docstring.

    ``workers`` — initial lane count.  ``pool`` — fleet mode: ``True``
    builds a service-owned :class:`~repro.runtime.transport.WorkerPool`,
    or pass a configured pool; every lane then drives one persistent
    worker process.  ``straggler_k`` — the EWMA deadline multiplier for
    straggler reclaim (``None`` disables stealing; completions are still
    observed).  ``max_active_bytes`` — perfmodel admission budget
    (``None`` = unlimited).  ``steal_poll_s`` — how often an idle lane
    re-checks for stale batches when everything is claimed.
    ``max_batch_attempts`` — bounded-retry/dead-letter policy: a batch
    handed out this many times without completing fails its job with a
    :class:`~repro.runtime.faults.DeadLetter` (kind=poison for repeat
    worker kills) instead of retrying forever.  ``lane_quarantine_s`` —
    cooldown before a crash-looping lane (``LaneHealth`` tripped on
    respawn) is readmitted.

    ``observer`` is the telemetry seam (``repro.obs.metrics``): an
    optional callable invoked as ``observer(event, **fields)`` for
    ``job_submit`` / ``job_finished(state=...)`` /
    ``batch_done(duration_s=..., stats=...)`` / ``steal`` /
    ``rejected_result`` / ``lane_fault`` / ``fault(kind=...)`` /
    ``lane_quarantine(worker=...)`` / ``lane_readmit(worker=...)`` /
    ``queue_{claim,requeue,
    complete,steal}`` (per-job WorkQueue events, prefix-forwarded).
    Observer errors are swallowed — telemetry must never perturb
    scheduling.  Also settable after construction (``svc.observer =``).
    """

    def __init__(self, *, workers: int = 1, pool=None,
                 straggler_k: Optional[float] = 3.0,
                 steal_poll_s: float = 0.05,
                 max_active_bytes: Optional[float] = None,
                 max_batch_attempts: int = 3,
                 lane_quarantine_s: float = 5.0,
                 observer=None):
        self.observer = observer
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._jobs: dict[int, _Job] = {}
        self._order: list[int] = []            # job ids, (-priority, id) order
        self._sessions: dict = {}              # coalescing cache (owned)
        self._threads: dict[str, threading.Thread] = {}
        self._removed: set[str] = set()
        self._closing = False
        self._seq = itertools.count()
        self._worker_seq = itertools.count()
        self._coalesced = 0
        self.straggler_k = straggler_k
        self.steal_poll_s = steal_poll_s
        self.max_active_bytes = max_active_bytes
        self._owns_pool = pool is True
        if pool is True:
            from repro.runtime.transport import WorkerPool
            pool = WorkerPool()
        self._pool = pool
        self._lane_batches: dict[str, int] = {}
        self._steals = 0                       # straggler re-issues handed out
        self._rejected_results = 0             # late completions discarded
        self._transport_faults = 0             # lane faults absorbed
        # fault taxonomy + dead-letter / lane-quarantine policy
        self.max_batch_attempts = max_batch_attempts
        self.lane_quarantine_s = lane_quarantine_s
        self._fault_counts = {k: 0 for k in KINDS}
        self._dead_letters = 0
        self._quarantined: dict[str, float] = {}   # lane → readmit monotonic
        self._lane_quarantines = 0
        self._lane_readmits = 0
        self._readmit_timers: list[threading.Timer] = []
        # test/ops hook: called as hook(job, batch_id, worker) right after a
        # worker claims a batch, before it executes — failure-injection
        # (tests), progress taps, tracing
        self.batch_hook = None
        for _ in range(workers):
            self.add_worker()

    @property
    def pool(self):
        """The fleet :class:`~repro.runtime.transport.WorkerPool` backing
        the lanes, or None for thread lanes (telemetry binders hook its
        ``observer`` here)."""
        return self._pool

    def _emit(self, event: str, **fields) -> None:
        if self.observer is not None:
            try:
                self.observer(event, **fields)
            except Exception:          # noqa: BLE001 — see class docstring
                pass

    def _finish(self, job: _Job, state: str) -> None:
        """Set a terminal job state (caller holds the lock) + telemetry."""
        job.state = state
        self._emit("job_finished", state=state)

    def _record_fault(self, job: _Job, fault: Fault) -> None:
        """Caller holds the lock: append to the job's fault history and the
        service-wide per-kind counters + telemetry (``fault`` event)."""
        job.faults.append(fault)
        self._fault_counts[fault.kind] += 1
        self._emit("fault", kind=fault.kind)

    def _queue_observer(self, event: str, **fields) -> None:
        """Per-job WorkQueue events, forwarded with a ``queue_`` prefix so
        one bound observer sees the whole scheduling surface."""
        self._emit("queue_" + event, **fields)

    # -- membership (elastic worker lanes) -----------------------------------
    def add_worker(self, name: Optional[str] = None) -> str:
        """Add one lane (scale-up is claim eligibility, nothing else); in
        fleet mode this also spawns the lane's persistent worker process."""
        with self._cond:
            if self._closing:
                raise RuntimeError("service is closed")
            if len(self.workers()) >= 1:
                # the same invariant submit() enforces, from the other side:
                # a multi-process runtime's broadcast schedule must stay
                # deterministic, so its jobs own the single lane exclusively
                for jid in self._order:
                    job = self._jobs[jid]
                    if (job.state in (PENDING, RUNNING)
                            and job.session.runtime.process_count > 1):
                        raise ValueError(
                            f"job {job.job_id} runs on the multi-process "
                            f"runtime {job.session.runtime.name!r} — scale-"
                            f"up would interleave its broadcast collectives "
                            f"across lanes; wait for it to finish")
            name = name or f"lane-{next(self._worker_seq)}"
            old = self._threads.get(name)
            if old is not None:
                # a removed-and-exited lane may be revived under its stable
                # ops name; a live one (even mid-drain) may not — two
                # threads must never share a lane identity
                if name in self._removed and not old.is_alive():
                    del self._threads[name]
                    self._removed.discard(name)
                else:
                    raise ValueError(f"worker {name!r} already exists")
            if self._pool is not None:
                w = self._pool.workers.get(name)
                if w is None or not w.alive:
                    self._pool.respawn(name)
            t = threading.Thread(target=self._worker_loop, args=(name,),
                                 name=f"sampling-service-{name}", daemon=True)
            self._threads[name] = t
            t.start()
            return name

    def remove_worker(self, name: str) -> None:
        """Drop a lane; its claimed batches requeue immediately (the queue
        re-offers them before fresh work) and any result it still produces
        is discarded by the ownership check — elasticity is exact because
        batches are idempotent.  A fleet lane's worker process is killed
        (its in-flight call fails over to the requeue path)."""
        with self._cond:
            self._removed.add(name)
            for jid in self._order:
                job = self._jobs[jid]
                if job.state in (PENDING, RUNNING):
                    job.queue.remove_worker(name)
            if self._pool is not None:
                self._pool.reap(name, kill=True)
            self._cond.notify_all()

    def workers(self) -> list[str]:
        with self._cond:
            return [n for n in self._threads if n not in self._removed]

    # -- lane health: crash-loop quarantine ----------------------------------
    def _quarantine_lane(self, name: str) -> None:
        """Crash-loop response (``LaneHealth`` tripped): retire the lane NOW
        — its batches requeue, its worker process is reaped — and schedule a
        cooldown readmit.  The cooldown IS the penalty: the lane returns to
        service with a clean fault window instead of respawning hot
        forever."""
        with self._cond:
            if self._closing or name in self._quarantined:
                return
            self._lane_quarantines += 1
            self._quarantined[name] = time.monotonic() + self.lane_quarantine_s
            if self._pool is not None:
                self._pool.health.forgive(name)
        self._emit("lane_quarantine", worker=name)
        self.remove_worker(name)
        t = threading.Timer(self.lane_quarantine_s, self._readmit_lane,
                            args=(name,))
        t.daemon = True
        with self._cond:
            if self._closing:
                return
            self._readmit_timers.append(t)
        t.start()

    def _readmit_lane(self, name: str) -> None:
        """Cooldown expiry: bring a quarantined lane back under its stable
        ops name (fresh worker process, clean fault window)."""
        with self._cond:
            self._quarantined.pop(name, None)
            if self._closing:
                return
            old = self._threads.get(name)
        if old is not None and old.is_alive():
            old.join(timeout=30)
        try:
            self.add_worker(name)
        except (ValueError, RuntimeError):
            return          # revived meanwhile, or the service closed
        with self._cond:
            self._lane_readmits += 1
        self._emit("lane_readmit", worker=name)

    # -- submission ----------------------------------------------------------
    def submit(self, source, config=None, *, n_samples: int, key,
               mesh=None, macro_batches: int = 1, priority: int = 0,
               skip_batches: Iterable[int] = (),
               resume: bool = False, checkpoint_dir: Optional[str] = None,
               stop_after_segments: Optional[int] = None,
               checkpoint_root: Optional[str] = None) -> JobHandle:
        """Queue one sampling job; returns immediately with a handle.

        ``source`` is anything a :class:`SamplingSession` accepts (MPS,
        GammaStore, store path) — jobs with an equal (source, config, mesh)
        triple coalesce onto one service-owned session, i.e. one resolved
        plan/jit cache — or an existing session (``config``/``mesh`` must
        then be None; the caller keeps ownership).

        ``n_samples`` is the job total; it divides over ``macro_batches``
        (paper N₁), each a restart-exact work item keyed by
        ``batch_key(key, b, macro_batches)``.  ``skip_batches`` marks batch
        ids already done elsewhere (idempotent restart: the driver skips
        batches whose output files exist).  ``priority``: higher runs
        first.  ``resume``/``checkpoint_dir``/``stop_after_segments`` are
        the single-batch session passthroughs; ``checkpoint_root`` gives a
        multi-batch streamed job per-batch checkpoint subdirs with
        automatic mid-chain resume (the ``run_queue`` contract).
        """
        from repro.api.session import SamplingSession
        from repro.core.perfmodel import Workload, job_admission_cost

        if macro_batches < 1:
            raise ValueError(f"macro_batches must be ≥ 1, got {macro_batches}")
        if n_samples % macro_batches:
            raise ValueError(f"n_samples={n_samples} must divide over "
                             f"{macro_batches} macro batches")
        skip = frozenset(int(b) for b in skip_batches)
        if any(b < 0 or b >= macro_batches for b in skip):
            raise ValueError(f"skip_batches {sorted(skip)} outside "
                             f"[0, {macro_batches})")
        if macro_batches > 1 and (resume or checkpoint_dir
                                  or stop_after_segments is not None):
            raise ValueError(
                "resume/checkpoint_dir/stop_after_segments address ONE "
                "chain walk — for a multi-batch job use checkpoint_root "
                "(per-batch subdirs, automatic resume)")
        if checkpoint_root and (resume or checkpoint_dir):
            raise ValueError(
                "checkpoint_root manages per-batch checkpoint dirs and "
                "resume automatically — don't combine it with "
                "resume/checkpoint_dir")

        if isinstance(source, SamplingSession):
            if config is not None or mesh is not None:
                raise ValueError("submitting an existing session: config/"
                                 "mesh are the session's — pass None")
            session = source
        else:
            session = self._coalesce_session(source, config, mesh)
        per_batch = n_samples // macro_batches
        # resolve (and validate) the plan up front: config errors surface at
        # submit time on the caller's thread, never as a failed job
        plan = session.plan(per_batch)
        if session.runtime.process_count > 1 and len(self.workers()) > 1:
            # every process of a multi-process runtime must issue its
            # broadcast collectives in the same order; one lane walking
            # jobs in the deterministic (-priority, id) order guarantees
            # that — concurrent lanes would interleave per thread timing
            # and desync (or deadlock) the cluster
            raise ValueError(
                f"runtime {session.runtime.name!r} spans "
                f"{session.runtime.process_count} processes — drive it "
                f"from a single-lane service (workers=1), not "
                f"{len(self.workers())} lanes, so the broadcast schedule "
                f"stays deterministic across processes")
        if self._pool is not None:
            # fleet lanes ship the v2 job-batch payload; the session side
            # must stay dispatchable (local single-process resolution, no
            # local chain-walk state — per-batch idempotence IS the remote
            # fault tolerance, exactly the backend="remote" contract)
            if (session.runtime.process_count > 1
                    or session.runtime.name not in ("local", "remote")):
                raise ValueError(
                    f"fleet lanes dispatch serialized job batches — the "
                    f"submitting session must resolve on a single-process "
                    f"local runtime, not {session.runtime.name!r}")
            if plan.scheme != "seq":
                raise ValueError(
                    f"fleet lanes resolve placement on the worker — submit "
                    f"with scheme AUTO/'seq', not {plan.scheme!r}")
            if (resume or checkpoint_dir or checkpoint_root
                    or stop_after_segments is not None):
                raise ValueError(
                    "fleet lanes have no local chain walk: per-batch "
                    "idempotence is the fault-tolerance story — restart "
                    "with skip_batches instead of resume/checkpoint options")

        w = Workload(n_samples=per_batch, n_sites=session.n_sites,
                     chi=session.chi, d=session.d, macro_batch=per_batch,
                     micro_batch=(plan.micro_batch or per_batch),
                     bytes_per_elt=session._elt_bytes)
        cost = job_admission_cost(w, session.config.hardware,
                                  n_batches=macro_batches - len(skip))

        with self._cond:
            if self._closing:
                raise RuntimeError("service is closed")
            queue = WorkQueue(macro_batches, observer=self._queue_observer)
            job = _Job(job_id=next(self._seq), session=session,
                       n_samples=n_samples, per_batch=per_batch,
                       n_batches=macro_batches, key=key, priority=priority,
                       queue=queue,
                       straggler=StragglerMitigator(
                           queue, k=(self.straggler_k or 3.0)),
                       skip=skip,
                       model_bytes=cost["resident_bytes"],
                       model_compute_s=cost["compute_s"],
                       resume=resume, checkpoint_dir=checkpoint_dir,
                       stop_after_segments=stop_after_segments,
                       checkpoint_root=checkpoint_root)
            self._emit("job_submit")
            for b in skip:
                job.queue.complete(b)
            if job.queue.finished:
                self._finish(job, DONE)
            self._jobs[job.job_id] = job
            self._order.append(job.job_id)
            self._order.sort(key=lambda j: (-self._jobs[j].priority, j))
            self._cond.notify_all()
        return JobHandle(self, job)

    def _coalesce_session(self, source, config, mesh):
        """One session (→ one compiled plan / streamed engine) per
        (source, config, mesh) cell, owned by the service."""
        from repro.api.session import SamplingSession
        from repro.data.gamma_store import GammaStore

        if isinstance(source, GammaStore):
            # dtypes are per-open constructor state, not recoverable from
            # the root — two handles on one root with different precision
            # must NOT share a session (bit-identity per handle)
            token = ("store", os.path.realpath(str(source.root)),
                     np.dtype(source.storage_dtype).name,
                     np.dtype(source.compute_dtype).name)
        elif isinstance(source, (str, os.PathLike)):
            token = ("store-path", os.path.realpath(str(source)))
        else:
            token = ("obj", id(source))
        cell = (token, config, mesh)
        with self._cond:
            sess = self._sessions.get(cell)
            if sess is not None:
                self._coalesced += 1
                return sess
        # build outside the lock (store probing does I/O)
        sess = SamplingSession(source, config, mesh=mesh)
        with self._cond:
            race = self._sessions.get(cell)
            if race is not None:
                self._coalesced += 1
                sess.close()
                return race
            self._sessions[cell] = sess
            return sess

    # -- scheduling ----------------------------------------------------------
    def _admission_view(self) -> tuple[list[int], list[int], float]:
        """(admitted job ids in schedule order, jobs queued by admission,
        modeled active bytes).  Caller holds the lock.  RUNNING jobs are
        grandfathered; PENDING jobs are admitted in priority order while
        the modeled footprint fits — and one job is always admitted, so a
        job bigger than the whole budget still runs, alone."""
        budget = self.max_active_bytes
        admitted: list[int] = []
        waiting: list[int] = []
        active = 0.0
        for jid in self._order:
            job = self._jobs[jid]
            if job.state == RUNNING:
                active += job.model_bytes
                admitted.append(jid)
        for jid in self._order:
            job = self._jobs[jid]
            if job.state != PENDING:
                continue
            if (budget is None or not admitted
                    or active + job.model_bytes <= budget):
                active += job.model_bytes
                admitted.append(jid)
            else:
                waiting.append(jid)
        return admitted, waiting, active

    def _next_task(self, worker: str) -> Optional[tuple[_Job, int]]:
        """Highest-priority claimable batch among *admitted* jobs (requeued
        before fresh within a job, courtesy of the WorkQueue); when nothing
        is claimable, a batch whose owner blew the EWMA deadline is stolen
        (straggler reclaim — last resort, it duplicates compute).  Caller
        holds the lock."""
        admitted, _, _ = self._admission_view()
        admitted_set = set(admitted)
        for jid in self._order:
            if jid not in admitted_set:
                continue
            job = self._jobs[jid]
            if job.state not in (PENDING, RUNNING):
                continue
            b = job.queue.claim(worker)
            if b is not None:
                job.state = RUNNING
                return job, b
        if self.straggler_k:
            for jid in self._order:
                job = self._jobs[jid]
                if job.state != RUNNING:
                    continue
                b = job.straggler.maybe_steal(worker)
                if b is not None:
                    self._steals += 1
                    self._emit("steal")
                    self._record_fault(job, Fault(
                        kind="timeout", batch=b,
                        message=f"straggler reclaim: batch {b} re-issued to "
                                f"{worker} after its owner exceeded the "
                                f"EWMA deadline"))
                    return job, b
        return None

    def _stealable(self) -> bool:
        """Whether an idle lane should poll for stale batches (a RUNNING
        job with claimed batches and an armed deadline).  Caller holds the
        lock."""
        if not self.straggler_k:
            return False
        for jid in self._order:
            job = self._jobs[jid]
            if (job.state == RUNNING
                    and job.straggler.deadline is not None
                    and any(r.owner is not None and not r.done
                            for r in job.queue.records.values())):
                return True
        return False

    def _worker_loop(self, name: str) -> None:
        while True:
            with self._cond:
                task = None
                while task is None:
                    if self._closing or name in self._removed:
                        return
                    task = self._next_task(name)
                    if task is None:
                        # an idle lane wakes on notify (new work) — or on a
                        # short poll when a straggler deadline might pass
                        self._cond.wait(timeout=(self.steal_poll_s
                                                 if self._stealable()
                                                 else None))
            self._run_batch(*task, worker=name)

    def _batch_checkpoint(self, job: _Job, b: int) -> tuple[Optional[str], bool]:
        """Per-batch checkpoint dir + whether to resume (run_queue contract:
        durable batch output supersedes the chain checkpoint).
        ``checkpoint_root`` applies to 1-batch jobs too, so the driver's
        ``--service --macro-batches 1`` keeps the synchronous path's
        mid-chain fault tolerance."""
        if job.checkpoint_root:
            if job.session.plan(job.per_batch).backend != "streamed":
                return None, False
            ck = batch_checkpoint_dir(job.checkpoint_root, b)
            os.makedirs(ck, exist_ok=True)
            return ck, has_chain_checkpoint(ck)
        return job.checkpoint_dir, job.resume

    def _run_batch_fleet(self, job: _Job, b: int, worker: str
                         ) -> tuple[np.ndarray, dict]:
        """Dispatch one claimed batch through the lane's persistent worker
        process: serialize the v2 job-batch payload (base key + batch
        identity; the worker folds the batch key itself) and block for the
        streamed-back block."""
        from repro.api.remote import build_payload

        store = job.session._ensure_store()     # locks internally; does I/O
        payload = build_payload(job.session.config, store, job.per_batch,
                                job.key,
                                job=JobBatch(job.job_id, b, job.n_batches))
        out = self._pool.call(worker, payload)
        w = self._pool.workers.get(worker)
        return out, {"transport_worker": worker,
                     "transport_worker_batches": w.batches if w else None}

    def _run_batch(self, job: _Job, b: int, worker: str) -> None:
        from repro.runtime.transport import TransportError

        hook = self.batch_hook
        if hook is not None:
            hook(job, b, worker)       # may remove this worker / cancel
        with self._cond:
            if job.state != RUNNING or worker in self._removed:
                return                 # cancelled/failed meanwhile, or killed
            # gang-scheduling: keep the streamed engine's prefetch pool warm
            # across the batch boundary only while SOMEONE still has a later
            # walk to run — the job's last batch must not pin a speculative
            # segment (pending includes this batch; a concurrent finisher
            # only costs one extra prefetch, the pre-fix behaviour)
            pipeline = job.queue.stats()["pending"] > 1
        ck = None
        t0 = time.monotonic()
        try:
            if self._pool is not None:
                out, stats = self._run_batch_fleet(job, b, worker)
            else:
                ck, resume = self._batch_checkpoint(job, b)
                out, stats = job.session._execute_batch(
                    job.per_batch, job.key,
                    job=JobBatch(job.job_id, b, job.n_batches),
                    resume=resume, checkpoint_dir=ck,
                    stop_after_segments=job.stop_after_segments,
                    pipeline=pipeline)
        except TransportError as e:
            # a LANE fault, not a job fault: the batch requeues (re-offered
            # before fresh work) and the lane's worker process respawns —
            # the recomputation is bit-identical (batch = f(seed, id)).
            # Unless the batch itself keeps killing lanes: after
            # max_batch_attempts hand-outs it dead-letters its JOB
            # (kind=poison) so one bad payload can't crash-loop the fleet.
            fault = classify(e, batch=b, lane=worker) or Fault(
                kind="transport", message=str(e), batch=b, lane=worker)
            with self._cond:
                self._transport_faults += 1
                self._emit("lane_fault")
                self._record_fault(job, fault)
                if job.queue.records[b].owner == worker:
                    job.queue.fail(worker)
                attempts = job.queue.attempts(b)
                if (job.state == RUNNING and not job.queue.records[b].done
                        and attempts >= self.max_batch_attempts):
                    kind = dead_letter_kind(
                        [f for f in job.faults if f.batch == b])
                    dl = Fault(kind=kind, batch=b, lane=worker,
                               message=f"batch {b} dead-lettered after "
                                       f"{attempts} attempts "
                                       f"(last: {fault.message})")
                    self._record_fault(job, dl)
                    job.dead_letter = {"batch": b, "attempts": attempts,
                                       "kind": kind}
                    job.error = DeadLetter(dl, FaultReport(
                        faults=list(job.faults),
                        dead_letter=job.dead_letter))
                    self._dead_letters += 1
                    self._finish(job, FAILED)
                self._cond.notify_all()
                if self._closing or worker in self._removed:
                    return
            try:
                self._pool.respawn(worker)
            except CrashLoopLane:
                self._quarantine_lane(worker)  # crash-looping: cool it down
            except OSError:
                self.remove_worker(worker)     # can't respawn: retire lane
            return
        except BaseException as e:     # noqa: BLE001 — reported via the job
            with self._cond:
                fault = classify(e, batch=b, lane=worker)
                if fault is not None:          # corruption/timeout/resource
                    self._record_fault(job, fault)
                if job.queue.records[b].owner == worker:
                    self._finish(job, FAILED)
                    job.error = e
                self._cond.notify_all()
            return
        duration = time.monotonic() - t0
        with self._cond:
            if not job.queue.complete(b, worker=worker):
                self._rejected_results += 1
                self._emit("rejected_result")
                return                 # ownership lost mid-compute: discard —
                                       # the requeued batch recomputes the
                                       # exact same block (batch = f(seed, id))
            job.straggler.observe_completion(duration)
            self._lane_batches[worker] = self._lane_batches.get(worker, 0) + 1
            self._emit("batch_done", duration_s=duration, stats=stats)
            if job.state == CANCELLED:
                return
            job.blocks[b] = np.asarray(out)
            job.batch_stats[b] = stats
            if job.queue.finished and job.state == RUNNING:
                self._finish(job, DONE)
            self._cond.notify_all()
        if ck is not None and job.checkpoint_root:
            import shutil
            shutil.rmtree(ck, ignore_errors=True)   # batch output is durable

    # -- introspection -------------------------------------------------------
    def stats(self) -> dict:
        """Service-wide snapshot with a STABLE schema — every key below is
        present on every call, zero-valued on an idle service, so scrapers
        (``repro.obs.metrics``, the gateway's ``/v1/stats``) never branch
        on missing keys:

        * ``jobs`` — count per lifecycle state, **all five states always
          present**: ``{"pending": 0, "running": 0, "done": 0,
          "failed": 0, "cancelled": 0, ...}``
        * ``sessions`` / ``coalesced_jobs`` — coalescing cache size, hits
        * ``workers`` — live lane count
        * ``queue_depth`` — pending batches over all active jobs
        * ``lane_batches`` — batches completed per lane name
        * ``admission`` — ``budget_bytes`` (None = unlimited),
          ``active_model_bytes``, ``admitted_jobs``, ``queued_jobs``,
          ``backpressure`` (bool)
        * ``stragglers`` — ``duplicates``, ``steals``, ``rejected_results``
        * ``faults`` / ``dead_letters`` — fault-taxonomy counters: every
          :data:`~repro.runtime.faults.KINDS` kind always present (zero
          when clean) + jobs failed by the bounded-retry dead-letter policy
        * ``transport`` — ALWAYS present: ``enabled`` (fleet mode?) plus
          the :meth:`WorkerPool.stats` keys (``workers``/``spawned``/
          ``reaped``/``faults``/``batches``/``dispatch_bytes``/
          ``lane_window_faults``/``backoff_seconds``, zeroed for thread
          lanes), ``lane_faults`` (faults absorbed by lanes), and the
          crash-loop surface: ``quarantined`` (lane names on cooldown),
          ``lane_quarantines`` / ``lane_readmits``.
        """
        with self._cond:
            states = {s: 0 for s in
                      (PENDING, RUNNING, DONE, FAILED, CANCELLED)}
            queue_depth = 0
            duplicates = 0
            for job in self._jobs.values():
                states[job.state] += 1
                if job.state in (PENDING, RUNNING):
                    queue_depth += job.queue.stats()["pending"]
                duplicates += job.straggler.duplicates
            admitted, waiting, active_bytes = self._admission_view()
            if self._pool is not None:
                transport = dict(self._pool.stats(), enabled=True)
            else:
                transport = {"enabled": False, "workers": 0, "spawned": 0,
                             "reaped": 0, "faults": 0, "batches": {},
                             "dispatch_bytes": 0, "lane_window_faults": {},
                             "backoff_seconds": 0.0}
            transport["lane_faults"] = self._transport_faults
            transport["quarantined"] = sorted(self._quarantined)
            transport["lane_quarantines"] = self._lane_quarantines
            transport["lane_readmits"] = self._lane_readmits
            return {"jobs": states, "sessions": len(self._sessions),
                    "faults": dict(self._fault_counts),
                    "dead_letters": self._dead_letters,
                    "coalesced_jobs": self._coalesced,
                    "workers": len(self.workers()),
                    "queue_depth": queue_depth,
                    "lane_batches": dict(self._lane_batches),
                    "admission": {
                        "budget_bytes": self.max_active_bytes,
                        "active_model_bytes": active_bytes,
                        "admitted_jobs": len(admitted),
                        "queued_jobs": len(waiting),
                        "backpressure": bool(waiting)},
                    "stragglers": {
                        "duplicates": duplicates, "steals": self._steals,
                        "rejected_results": self._rejected_results},
                    "transport": transport}

    def purge(self) -> int:
        """Drop finished (done/failed/cancelled) jobs from the service
        table; returns how many were dropped.  A long-lived serving process
        calls this periodically so consumed jobs' sample blocks don't
        accumulate for the service's lifetime.  Handles the caller still
        holds keep answering (each handle owns its job record) — the blocks'
        memory is reclaimed once those handles go away.  The service never
        purges on its own: dropping results the caller hasn't consumed is
        the caller's decision."""
        with self._cond:
            dead = [j for j, job in self._jobs.items()
                    if job.state in (DONE, FAILED, CANCELLED)]
            for j in dead:
                del self._jobs[j]
            self._order = [j for j in self._order if j in self._jobs]
            return len(dead)

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Stop the lanes (running batches finish; pending jobs that never
        completed report cancelled), reap fleet workers, and close
        service-owned sessions."""
        with self._cond:
            if self._closing:
                return
            self._closing = True
            for job in self._jobs.values():
                if job.state in (PENDING, RUNNING):
                    self._finish(job, CANCELLED)
            timers = list(self._readmit_timers)
            self._cond.notify_all()
        for t in timers:
            t.cancel()
        for t in self._threads.values():
            t.join(timeout=300)
        if self._pool is not None:
            for name in list(self._threads):
                self._pool.reap(name)
            if self._owns_pool:
                self._pool.close()
        for sess in self._sessions.values():
            sess.close()
        self._sessions.clear()

    def __enter__(self) -> "SamplingService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


__all__ = ["CANCELLED", "DONE", "FAILED", "JobBatch", "JobCancelled",
           "JobHandle", "PENDING", "RUNNING", "SamplingService", "batch_key"]
