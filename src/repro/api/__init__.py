"""Unified sampling API — one front door for every FastMPS mode.

One :class:`SamplingSession` call covers the whole design matrix
{in-memory, streamed, remote} × {local, multihost, remote runtime} ×
{seq, dp, tp_single, tp_double} × {fixed χ, dynamic χ} × {whole-batch,
micro-batched}, with fault-tolerant macro batches and bit-exact mid-chain
resume.

Execution is split along two orthogonal, independently-pluggable axes:

* the **data plane** (``backend=`` — :func:`register_backend`): how a
  resolved plan walks the chain;
* the **cluster runtime** (``runtime=`` — ``repro.api.runtime``): where
  processes/devices live and how Γ bytes move between them — ``local``,
  ``multihost`` (paper §3.1 process-0-reads-then-broadcasts), ``remote``
  (serialized-config dispatch, ``repro.api.remote``).

so a new execution strategy or a new deployment shape never forks the
driver, examples, or tests.  The legacy entry points
(``core.parallel.multilevel_sample``/``dp_sample``/``baseline19_sample``
and ``engine.stream_sample``) were removed one release after this facade
shipped, as scheduled — every caller goes through the session.

On top of the session sits the **service layer**
(:class:`SamplingService`): sampling as asynchronous *jobs* —
``submit(...) -> JobHandle`` with ``result``/``stream``/``status``/
``progress``/``cancel``, priority scheduling, elastic worker lanes over
the macro-batch :class:`~repro.runtime.elastic.WorkQueue`, plan
coalescing, and gang-scheduled cross-batch prefetch.
``SamplingSession.sample``/``run_queue`` are synchronous wrappers over a
one-lane service, so the job path is the ONLY execution path.
"""
from repro.api import remote  # noqa: F401  (registers the remote runtime)
from repro.api.backends import (Backend, SampleRequest, available_backends,
                                get_backend, register_backend)
from repro.api.config import (AUTO, SamplerConfig, SessionPlan, resolve_plan)
from repro.api.remote import RemoteRuntime
from repro.api.runtime import (ClusterRuntime, LocalRuntime,
                               MultiHostRuntime, available_runtimes,
                               emulated_cluster, get_runtime,
                               register_runtime, resolve_runtime)
from repro.api.service import (JobBatch, JobCancelled, JobHandle,
                               SamplingService, batch_key)
from repro.api.session import SamplingSession
from repro.runtime.transport import TransportError, WorkerPool

__all__ = [
    "AUTO", "Backend", "ClusterRuntime", "JobBatch", "JobCancelled",
    "JobHandle", "LocalRuntime", "MultiHostRuntime", "RemoteRuntime",
    "SampleRequest", "SamplerConfig", "SamplingService", "SamplingSession",
    "SessionPlan", "TransportError", "WorkerPool", "available_backends",
    "available_runtimes", "batch_key", "get_backend", "get_runtime",
    "emulated_cluster", "register_backend", "register_runtime",
    "resolve_plan", "resolve_runtime",
]
