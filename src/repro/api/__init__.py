"""Unified sampling API — one front door for every FastMPS mode.

One :class:`SamplingSession` call covers the whole design matrix
{in-memory, streamed} × {seq, dp, tp_single, tp_double} × {fixed χ,
dynamic χ} × {whole-batch, micro-batched}, with fault-tolerant macro
batches and bit-exact mid-chain resume.  Backends are registry entries
(:func:`register_backend`) — a new execution strategy never forks the
driver, examples, or tests.

The legacy entry points (``core.parallel.multilevel_sample``/``dp_sample``/
``baseline19_sample`` and ``engine.stream_sample``) are deprecation-shimmed
and will be removed one release after this facade; they emit
``DeprecationWarning`` pointing here.
"""
from repro.api.backends import (Backend, SampleRequest, available_backends,
                                get_backend, register_backend)
from repro.api.config import (AUTO, SamplerConfig, SessionPlan, resolve_plan)
from repro.api.session import SamplingSession

__all__ = [
    "AUTO", "Backend", "SampleRequest", "SamplerConfig", "SamplingSession",
    "SessionPlan", "available_backends", "get_backend", "register_backend",
    "resolve_plan",
]
