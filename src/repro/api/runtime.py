"""`ClusterRuntime` — where processes/devices live and how bytes move.

A backend used to be a monolith: ``inmem``/``streamed`` each hard-coded
their own placement, fetch, and collective story.  This module splits the
execution API into two orthogonal axes:

* the **data plane** (``repro.api.backends``): how a resolved
  :class:`SessionPlan` walks the chain — in-memory scan vs. segment-streamed;
* the **runtime** (this module): where the participating processes live and
  how host bytes move between them — ``local`` (one process, collectives are
  no-ops), ``multihost`` (the paper's §3.1 process-0-reads-then-broadcast
  over the interconnect), ``remote`` (dispatch a serialized
  :class:`SamplerConfig` to a worker, see ``repro.api.remote``).

Every runtime implements the same small protocol::

    runtime.process_index / runtime.process_count / runtime.is_root
    runtime.mesh(model_parallel)       # device mesh over the global view
    runtime.broadcast_segment(payload) # root sends, everyone returns it
    runtime.barrier()                  # line the processes up
    runtime.io_counters()              # interconnect/dispatch byte counters
    runtime.submit(payload)            # remote-dispatch entry (see remote.py)

so ``streamed × multihost`` is a *config cell* —
``SamplerConfig(backend="streamed", runtime="multihost")`` — rather than a
new backend class, and every future scale concern (elastic workers,
straggler mitigation, RPC dispatch) is a runtime entry instead of a
backend fork.

The wire format of :meth:`broadcast_segment` is the **storage format** of
:class:`repro.data.gamma_store.GammaStore` (bf16-packed Γ when the store is
bf16 — §3.3.2's FP16 trick halves broadcast bytes exactly as it halves
disk bytes), and every process — root included — decodes through the same
``gamma_store.decode_segment`` the local read path uses, so a multihost
walk is bit-identical to a local one by construction.

Multi-process behaviour is testable on one machine:
:func:`emulated_cluster` builds N :class:`MultiHostRuntime` instances wired
through an in-process interconnect — the same code path a real
``jax.distributed`` deployment takes, minus the network.
"""
from __future__ import annotations

import threading
import queue as queue_mod
from typing import Callable, Optional

import numpy as np

AUTO = "auto"

_RUNTIME_REGISTRY: dict[str, Callable[[], "ClusterRuntime"]] = {}


def register_runtime(name: str):
    """Decorator: register a zero-arg runtime factory under ``name``."""
    def deco(factory):
        _RUNTIME_REGISTRY[name] = factory
        return factory
    return deco


def available_runtimes() -> list[str]:
    return sorted(_RUNTIME_REGISTRY)


def get_runtime(name: str) -> "ClusterRuntime":
    try:
        return _RUNTIME_REGISTRY[name]()
    except KeyError:
        raise ValueError(f"no runtime {name!r} registered; "
                         f"have {available_runtimes()}") from None


def resolve_runtime(spec) -> "ClusterRuntime":
    """AUTO → local on one process; a name → registry; an instance → itself.

    Tests and emulated deployments pass runtime *instances* (e.g. one member
    of :func:`emulated_cluster`); configs written to disk pass names.
    """
    if spec is None or spec == AUTO:
        return get_runtime("local")
    if isinstance(spec, ClusterRuntime):
        return spec
    if isinstance(spec, str):
        return get_runtime(spec)
    raise TypeError(f"runtime must be a name, a ClusterRuntime instance, or "
                    f"AUTO — got {type(spec).__name__}")


def _payload_nbytes(payload) -> int:
    if payload is None:
        return 0
    return sum(int(v.nbytes) for v in payload.values()
               if isinstance(v, np.ndarray))


def payload_to_bytes(payload: dict) -> np.ndarray:
    """Segment wire payload → one flat uint8 buffer (npz framing).

    ``jax.experimental.multihost_utils.broadcast_one_to_all`` needs every
    process to supply the *same* pytree of arrays — a dict with variable
    shapes and non-array metadata is not broadcastable as-is, but
    (length, bytes) is: see :class:`JaxMultiHostRuntime`.  Dtypes ride as
    names; the Γ bytes stay in storage format (no recompression).  The
    store's segment checksum (``crc``) rides along so a corrupt wire blob
    is rejected at ``decode_segment`` instead of sampled from.

    A root-side read fault also has to cross the wire (every process must
    fail the same round, not hang in a collective): a payload carrying an
    ``error`` string (plus an optional structured ``fault`` dict) encodes
    as a small error frame instead of a segment."""
    import io
    import json

    bio = io.BytesIO()
    if payload.get("error") is not None:
        np.savez(bio,
                 error=np.frombuffer(str(payload["error"]).encode(),
                                     dtype=np.uint8),
                 fault=np.frombuffer(
                     json.dumps(payload.get("fault") or {}).encode(),
                     dtype=np.uint8),
                 start=np.asarray(int(payload.get("start", -1)),
                                  dtype=np.int64))
        return np.frombuffer(bio.getvalue(), dtype=np.uint8)
    crc = payload.get("crc")
    np.savez(bio, gamma=payload["gamma"], lam=payload["lam"],
             gshape=np.asarray(payload["gshape"], dtype=np.int64),
             two_byte=np.asarray(bool(payload["two_byte"])),
             start=np.asarray(int(payload["start"]), dtype=np.int64),
             storage_dtype=np.asarray(
                 np.dtype(payload["storage_dtype"]).name),
             compute_dtype=np.asarray(
                 np.dtype(payload["compute_dtype"]).name),
             crc=np.asarray(-1 if crc is None else int(crc),
                            dtype=np.int64))
    return np.frombuffer(bio.getvalue(), dtype=np.uint8)


def payload_from_bytes(buf: np.ndarray) -> dict:
    """Inverse of :func:`payload_to_bytes`."""
    import io
    import json

    import jax.numpy as jnp

    with np.load(io.BytesIO(np.asarray(buf, dtype=np.uint8).tobytes())) as z:
        if "error" in z.files:
            return {"error": z["error"].tobytes().decode(),
                    "fault": json.loads(z["fault"].tobytes().decode()),
                    "start": int(z["start"])}
        crc = int(z["crc"]) if "crc" in z.files else -1
        return {"gamma": z["gamma"], "lam": z["lam"],
                "gshape": tuple(int(x) for x in z["gshape"]),
                "two_byte": bool(z["two_byte"]),
                "start": int(z["start"]),
                "storage_dtype": getattr(jnp, str(z["storage_dtype"])),
                "compute_dtype": getattr(jnp, str(z["compute_dtype"])),
                "crc": None if crc < 0 else crc}


def dict_to_bytes(payload: dict) -> np.ndarray:
    """Generic dict-of-arrays → flat uint8 buffer (npz framing) for the
    fixed-structure two-round collective transports (length, then blob).
    Used by the sharded walk's env-handoff and block-gather payloads, whose
    keys — unlike the Γ segment payload's — are not known up front."""
    import io

    bio = io.BytesIO()
    np.savez(bio, **{k: np.asarray(v) for k, v in payload.items()})
    return np.frombuffer(bio.getvalue(), dtype=np.uint8)


def dict_from_bytes(buf: np.ndarray) -> dict:
    """Inverse of :func:`dict_to_bytes`."""
    import io

    with np.load(io.BytesIO(np.asarray(buf, dtype=np.uint8).tobytes())) as z:
        return {k: z[k] for k in z.files}


class ClusterRuntime:
    """Where processes/devices live and how bytes move between them."""
    name = "abstract"

    # -- topology ------------------------------------------------------------
    @property
    def process_index(self) -> int:
        return 0

    @property
    def process_count(self) -> int:
        return 1

    @property
    def is_root(self) -> bool:
        return self.process_index == 0

    def mesh(self, model_parallel: int = 1):
        """Device mesh over this runtime's global device view (the default
        covers whatever jax exposes to this process — forced host devices
        included, which is what the emulated tests use)."""
        from repro.launch.mesh import make_host_mesh
        return make_host_mesh(model=model_parallel)

    # -- collectives (host-side, segment granularity) ------------------------
    def broadcast_segment(self, payload: Optional[dict], root: int = 0
                          ) -> dict:
        """Root sends ``payload`` (a dict of host arrays + metadata) to every
        process; every caller — root included — returns the payload.  The
        single-process default is a no-op passthrough."""
        if payload is None:
            raise ValueError(f"runtime {self.name!r} has one process — "
                             f"broadcast_segment needs the payload on it")
        return payload

    def barrier(self) -> None:
        """Line the processes up (no-op with one process)."""

    # -- point-to-point (sharded data plane, repro.shard) --------------------
    def send(self, dst: int, payload: dict, tag=None) -> None:
        """Ship a dict-of-host-arrays payload to process ``dst`` (the
        sharded walk's env handoff).  ``tag`` disambiguates concurrent
        streams between the same pair (the walk tags by boundary site)."""
        raise NotImplementedError(f"runtime {self.name!r} has no "
                                  f"point-to-point transport")

    def recv(self, src: int, tag=None) -> dict:
        """Blocking receive of the matching :meth:`send` from ``src``."""
        raise NotImplementedError(f"runtime {self.name!r} has no "
                                  f"point-to-point transport")

    def observe_handoff(self, src: int, tag=None) -> None:
        """Called by every process that is NEITHER endpoint of a handoff.

        A true point-to-point fabric (the emulated interconnect) ignores
        this; transports built on global collectives (a real
        ``jax.distributed`` launch routes send/recv through
        ``broadcast_one_to_all``) need every process to participate in
        every transfer — this is the bystander's participation hook."""

    def allreduce_min(self, value: int) -> int:
        """Global min over one int per process (the cluster-synchronized
        resume agreement).  Identity with one process."""
        return int(value)

    def allgather_payloads(self, payload: dict) -> list[dict]:
        """Every process contributes one dict-of-arrays payload; every
        process returns all of them, rank-ordered (the sharded walk's final
        sample-block gather).  Single-process: ``[payload]``."""
        return [payload]

    def compute_lock(self):
        """Context manager held around one segment's device execution.

        A no-op everywhere except the *emulated* cluster: there, N
        "processes" share one local XLA backend, and two collective
        programs executing concurrently can interleave their rendezvous
        participants and deadlock the device thread pool — something a
        real multi-process launch cannot do (one program per process, own
        devices).  The emulated fabric therefore serializes segment
        compute across its members; broadcast/prefetch still overlap."""
        import contextlib
        return contextlib.nullcontext()

    # -- instrumentation ------------------------------------------------------
    def io_counters(self) -> dict:
        """Monotonic byte/segment counters for everything this runtime moved
        over the interconnect (or dispatched to a worker).  Engines report
        per-walk deltas of these next to the GammaStore's disk counters."""
        return {"broadcast_send_bytes": 0, "broadcast_recv_bytes": 0,
                "broadcast_segments": 0, "dispatch_bytes": 0,
                "p2p_send_bytes": 0, "p2p_recv_bytes": 0, "p2p_msgs": 0}

    # -- remote dispatch ------------------------------------------------------
    def submit(self, payload: dict) -> np.ndarray:
        """Execute one serialized sampling request (see ``repro.api.remote``
        for the payload schema) wherever this runtime's workers live."""
        raise NotImplementedError(f"runtime {self.name!r} has no dispatch "
                                  f"transport")

    # -- lifecycle ------------------------------------------------------------
    def close(self) -> None:
        """Release transport state (persistent workers, sockets).  A no-op
        for runtimes that hold none; sessions call it on runtimes they
        resolved from a *name* (instances passed in stay the caller's)."""


@register_runtime("local")
class LocalRuntime(ClusterRuntime):
    """Today's behaviour: one process, collectives are no-ops.

    ``submit`` still works — it executes the serialized request in-process
    (the loopback transport), so ``backend="remote"`` is exercisable without
    any worker infrastructure and the dispatch path never rots.
    """
    name = "local"

    def __init__(self):
        self._dispatch_bytes = 0
        self._dispatches = 0

    def io_counters(self) -> dict:
        out = super().io_counters()
        out.update(dispatch_bytes=self._dispatch_bytes,
                   dispatches=self._dispatches)
        return out

    def submit(self, payload: dict) -> np.ndarray:
        import json

        from repro.api.remote import execute_payload
        self._dispatch_bytes += len(json.dumps(payload).encode())
        self._dispatches += 1
        return execute_payload(payload)


class _Interconnect:
    """In-process stand-in for the multi-host fabric: one queue per process
    plus a shared barrier.  Queues are unbounded so the root may run ahead
    of slow receivers (each *engine* still bounds its own live segments at
    two; the fabric models wire buffering, not device memory)."""

    def __init__(self, n_processes: int, timeout: float = 120.0):
        self.n = n_processes
        self.timeout = timeout
        self.queues = [queue_mod.Queue() for _ in range(n_processes)]
        # separate lane for point-to-point traffic (env handoffs, block
        # gathers): a sharded walk must not have its handoff dequeue a
        # broadcast segment some other plan left in flight
        self.p2p_queues = [queue_mod.Queue() for _ in range(n_processes)]
        self.barrier = threading.Barrier(n_processes)
        # allreduce scratch: one slot per process.  Each process overwrites
        # only its OWN slot before the first barrier and reads between the
        # two barriers, so rounds never need clearing (stale values are
        # overwritten, and the trailing barrier keeps a fast process from
        # starting round k+1 before a slow one has read round k).
        self.reduce_slots = [0] * n_processes
        # emulated processes share one XLA backend: collective programs
        # from two members must not execute concurrently (their rendezvous
        # would interleave and deadlock the device pool) — see
        # ClusterRuntime.compute_lock
        self.compute = threading.Lock()

    def send(self, dst: int, msg) -> None:
        self.queues[dst].put(msg)

    def recv(self, dst: int):
        try:
            return self.queues[dst].get(timeout=self.timeout)
        except queue_mod.Empty:
            raise TimeoutError(
                f"process {dst} waited >{self.timeout}s for a broadcast — "
                f"is the root walking the same segment schedule?") from None

    def send_p2p(self, dst: int, msg) -> None:
        self.p2p_queues[dst].put(msg)

    def recv_p2p(self, dst: int):
        try:
            return self.p2p_queues[dst].get(timeout=self.timeout)
        except queue_mod.Empty:
            raise TimeoutError(
                f"process {dst} waited >{self.timeout}s for a point-to-point "
                f"payload — is the predecessor owner still walking?") from None


class MultiHostRuntime(ClusterRuntime):
    """Paper §3.1: process 0 reads each Γ segment once and broadcasts it.

    One instance per participating process.  The transport is pluggable:
    :func:`emulated_cluster` wires N instances through an in-process
    :class:`_Interconnect` (tests, benches, single-machine smoke runs); a
    real deployment constructs one per host over ``jax.distributed`` (see
    :func:`jax_multihost_runtime`).
    """
    name = "multihost"

    def __init__(self, process_index: int, process_count: int,
                 fabric: _Interconnect):
        self._index = process_index
        self._count = process_count
        self._fabric = fabric
        self._send_bytes = 0
        self._recv_bytes = 0
        self._segments = 0
        self._p2p_send = 0
        self._p2p_recv = 0
        self._p2p_msgs = 0
        # out-of-order p2p delivery: messages that arrived while waiting
        # for a different (src, tag) stream, keyed for later pickup
        self._p2p_buf: dict = {}

    @property
    def process_index(self) -> int:
        return self._index

    @property
    def process_count(self) -> int:
        return self._count

    def broadcast_segment(self, payload: Optional[dict], root: int = 0
                          ) -> dict:
        if self._index == root:
            if payload is None:
                raise ValueError("the root process must supply the payload")
            nbytes = _payload_nbytes(payload)
            for dst in range(self._count):
                if dst != root:
                    self._fabric.send(dst, payload)
            self._send_bytes += nbytes * (self._count - 1)
        else:
            if payload is not None:
                raise ValueError(
                    f"process {self._index} is not the broadcast root "
                    f"({root}) but supplied a payload — only the root may "
                    f"touch the GammaStore")
            payload = self._fabric.recv(self._index)
            self._recv_bytes += _payload_nbytes(payload)
        self._segments += 1
        return payload

    def barrier(self) -> None:
        self._fabric.barrier.wait(timeout=self._fabric.timeout)

    # -- point-to-point (sharded data plane) ---------------------------------
    def send(self, dst: int, payload: dict, tag=None) -> None:
        if not 0 <= dst < self._count:
            raise ValueError(f"send dst {dst} outside [0, {self._count})")
        if dst == self._index:
            raise ValueError(f"process {self._index} sending to itself — "
                             f"an owner handoff never loops back")
        self._fabric.send_p2p(dst, (self._index, tag, payload))
        self._p2p_send += _payload_nbytes(payload)
        self._p2p_msgs += 1

    def recv(self, src: int, tag=None) -> dict:
        want = (src, tag)
        buf = self._p2p_buf
        while not buf.get(want):
            s, t, payload = self._fabric.recv_p2p(self._index)
            # count on arrival INTO this process, buffered or not
            self._p2p_recv += _payload_nbytes(payload)
            self._p2p_msgs += 1
            buf.setdefault((s, t), []).append(payload)
        return buf[want].pop(0)

    def allreduce_min(self, value: int) -> int:
        f = self._fabric
        f.reduce_slots[self._index] = int(value)
        f.barrier.wait(timeout=f.timeout)
        out = min(f.reduce_slots)
        f.barrier.wait(timeout=f.timeout)
        return out

    def allgather_payloads(self, payload: dict) -> list[dict]:
        # rank-ordered rounds; sends never block (unbounded queues), so a
        # process fires all its sends in its own round and then drains the
        # others' — deadlock-free without any global scheduler
        out = []
        for r in range(self._count):
            if r == self._index:
                for dst in range(self._count):
                    if dst != self._index:
                        self.send(dst, payload, tag=("allgather", r))
                out.append(payload)
            else:
                out.append(self.recv(r, tag=("allgather", r)))
        return out

    def compute_lock(self):
        import contextlib
        if self._fabric is not None and hasattr(self._fabric, "compute"):
            return self._fabric.compute
        return contextlib.nullcontext()

    def io_counters(self) -> dict:
        out = super().io_counters()
        out.update(broadcast_send_bytes=self._send_bytes,
                   broadcast_recv_bytes=self._recv_bytes,
                   broadcast_segments=self._segments,
                   p2p_send_bytes=self._p2p_send,
                   p2p_recv_bytes=self._p2p_recv,
                   p2p_msgs=self._p2p_msgs)
        return out


def emulated_cluster(n_processes: int, timeout: float = 120.0
                     ) -> list[MultiHostRuntime]:
    """N multihost runtimes sharing an in-process interconnect.

    Drive one engine/session per returned runtime (concurrently — e.g. one
    thread each, the way tests/test_api.py does) and the root alone reads
    the GammaStore while every process emits bit-identical samples."""
    if n_processes < 2:
        raise ValueError(f"an emulated cluster needs ≥ 2 processes, got "
                         f"{n_processes}")
    fabric = _Interconnect(n_processes, timeout=timeout)
    return [MultiHostRuntime(i, n_processes, fabric)
            for i in range(n_processes)]


class JaxMultiHostRuntime(MultiHostRuntime):  # pragma: no cover — ≥2 procs
    """The same broadcast over a real ``jax.distributed`` launch.

    ``multihost_utils.broadcast_one_to_all`` requires every process to
    supply an identically-structured pytree of arrays, so the
    variable-shape payload goes over in two fixed-structure rounds: a
    (1,)-int64 length every process can pre-shape, then the npz-framed
    byte buffer (:func:`payload_to_bytes` — storage format, no
    recompression; the round-trip itself is unit-tested in-process).  The
    in-process :class:`MultiHostRuntime` above exercises the identical
    engine/session wiring in CI."""

    def __init__(self):
        import jax
        super().__init__(jax.process_index(), jax.process_count(),
                         fabric=None)

    def broadcast_segment(self, payload, root: int = 0) -> dict:
        from jax.experimental import multihost_utils as mhu
        if self.is_root:
            if payload is None:
                raise ValueError("the root process must supply the payload")
            blob = payload_to_bytes(payload)
            length = np.asarray([blob.size], dtype=np.int64)
        else:
            blob = None
            length = np.zeros((1,), dtype=np.int64)
        length = np.asarray(
            mhu.broadcast_one_to_all(length, is_source=self.is_root))
        if not self.is_root:
            blob = np.zeros((int(length[0]),), dtype=np.uint8)
        blob = np.asarray(
            mhu.broadcast_one_to_all(blob, is_source=self.is_root))
        if self.is_root:
            self._send_bytes += int(blob.size) * (self._count - 1)
        else:
            payload = payload_from_bytes(blob)
            self._recv_bytes += int(blob.size)
        self._segments += 1
        return payload

    def barrier(self) -> None:
        from jax.experimental import multihost_utils as mhu
        mhu.sync_global_devices("repro.api.runtime.barrier")

    # -- point-to-point over the global collective ---------------------------
    # ``jax.distributed`` exposes no true send/recv; a handoff is a
    # src-rooted broadcast every process participates in (sender=send,
    # receiver=recv, everyone else=observe_handoff — the engine's sharded
    # walk calls exactly one of the three on each process per boundary, so
    # the rounds line up globally).  Env payloads are (N, χ) — tiny next to
    # the Γ broadcast this plane replaces — so the collective detour costs
    # O(N·χ) per boundary, still O(chain) overall.
    def _bcast_dict_from(self, src: int, payload) -> dict:
        from jax.experimental import multihost_utils as mhu
        mine = self._index == src
        if mine:
            blob = dict_to_bytes(payload)
            length = np.asarray([blob.size], dtype=np.int64)
        else:
            blob = None
            length = np.zeros((1,), dtype=np.int64)
        length = np.asarray(mhu.broadcast_one_to_all(length, is_source=mine))
        if not mine:
            blob = np.zeros((int(length[0]),), dtype=np.uint8)
        blob = np.asarray(mhu.broadcast_one_to_all(blob, is_source=mine))
        return payload if mine else dict_from_bytes(blob)

    def send(self, dst: int, payload: dict, tag=None) -> None:
        self._p2p_send += _payload_nbytes(payload)
        self._p2p_msgs += 1
        self._bcast_dict_from(self._index, payload)

    def recv(self, src: int, tag=None) -> dict:
        payload = self._bcast_dict_from(src, None)
        self._p2p_recv += _payload_nbytes(payload)
        self._p2p_msgs += 1
        return payload

    def observe_handoff(self, src: int, tag=None) -> None:
        self._bcast_dict_from(src, None)

    def allreduce_min(self, value: int) -> int:
        from jax.experimental import multihost_utils as mhu
        vals = mhu.process_allgather(np.asarray([value], dtype=np.int64))
        return int(np.min(vals))

    def allgather_payloads(self, payload: dict) -> list[dict]:
        return [self._bcast_dict_from(r, payload if r == self._index
                                      else None)
                for r in range(self._count)]


@register_runtime("multihost")
def jax_multihost_runtime() -> MultiHostRuntime:
    """The real multi-process entry: requires ``jax.distributed`` to be
    initialized (jax.process_count() > 1).  Single-process sessions that
    want the broadcast code path pass an :func:`emulated_cluster` member as
    ``SamplerConfig(runtime=<instance>)`` instead."""
    import jax

    if jax.process_count() < 2:
        raise ValueError(
            "runtime='multihost' needs a jax.distributed launch with ≥ 2 "
            "processes (jax.process_count() == "
            f"{jax.process_count()}); for single-machine tests pass an "
            "emulated_cluster(n) member as SamplerConfig(runtime=<instance>)")
    return JaxMultiHostRuntime()


__all__ = [
    "AUTO", "ClusterRuntime", "JaxMultiHostRuntime", "LocalRuntime",
    "MultiHostRuntime", "available_runtimes", "dict_from_bytes",
    "dict_to_bytes", "emulated_cluster", "get_runtime",
    "payload_from_bytes", "payload_to_bytes", "register_runtime",
    "resolve_runtime",
]
